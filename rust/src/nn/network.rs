//! Network assembly: parameters, shape inference, and the forward pass.
//!
//! Parameter initialization replicates `python/compile/model.py::init_params`
//! bit-for-bit (same PRNG, same order, same f32 rounding) so the Rust
//! pipeline and the AOT model artifact compute over identical weights.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{Activation, LayerSpec, NetConfig};
use crate::mm::job::JobClass;
use crate::mm::{OperandView, TileGrid};
use crate::tensor::Tensor;
use crate::util::rng;

use super::{batchnorm::batchnorm, conv, im2col::im2col, pool, softmax};

/// Shape flowing between layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    Chw(usize, usize, usize),
    Flat(usize),
}

impl Shape {
    pub fn len(&self) -> usize {
        match self {
            Shape::Chw(c, h, w) => c * h * w,
            Shape::Flat(n) => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dims(&self) -> Vec<usize> {
        match self {
            Shape::Chw(c, h, w) => vec![*c, *h, *w],
            Shape::Flat(n) => vec![*n],
        }
    }
}

/// One named parameter tensor, backed by an `Arc` so the GEMM-operand
/// cache (`Network::weight_arcs`) shares the same allocation instead of
/// duplicating every CONV/FC weight matrix per loaded network.  Params
/// are init-once by contract — hence no mutable access.
#[derive(Debug, Clone)]
pub struct Param {
    pub layer: usize,
    pub name: &'static str,
    shape: Vec<usize>,
    data: Arc<Vec<f32>>,
}

impl Param {
    fn new(layer: usize, name: &'static str, shape: &[usize], data: Vec<f32>) -> Param {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "param {name} shape/data mismatch"
        );
        Param {
            layer,
            name,
            shape: shape.to_vec(),
            data: Arc::new(data),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Cheap handle on the backing allocation (job operand sharing).
    pub fn shared(&self) -> Arc<Vec<f32>> {
        Arc::clone(&self.data)
    }
}

/// Descriptor of one CONV layer's GEMM (job geometry for the coordinator).
#[derive(Debug, Clone)]
pub struct ConvLayerInfo {
    /// Layer index within the network.
    pub layer_idx: usize,
    /// 0-based index among CONV layers only.
    pub conv_idx: usize,
    pub filters: usize,
    pub size: usize,
    pub stride: usize,
    pub pad: usize,
    pub activation: Activation,
    pub in_shape: (usize, usize, usize),
    pub out_shape: (usize, usize, usize),
    /// GEMM tiling (M=filters, N=C·K², P=OH·OW).
    pub grid: TileGrid,
}

/// A fully-materialized network: config + parameters + shape table.
#[derive(Debug, Clone)]
pub struct Network {
    pub config: NetConfig,
    pub params: Vec<Param>,
    /// Output shape of every layer (same indexing as `config.layers`).
    pub shapes: Vec<Shape>,
    tile_size: usize,
    /// Arc handles onto the GEMM weight operands of CONV/FC layers
    /// (indexed by layer) — the **same allocations** as the [`Param`]s
    /// (params are Arc-backed), so the per-frame hot path never copies a
    /// weight matrix and each network stores its weights exactly once.
    weight_arcs: Vec<Option<Arc<Vec<f32>>>>,
    /// Per-layer CONV weight prepack: the dense (M,N) weight matrix in the
    /// blocked (rows·K,TS,TS) job layout ([`TileGrid::pack_a_tiles`]),
    /// built **once at network load**.  Every frame's CONV-tile jobs carry
    /// views into these buffers — the per-dispatch weight re-pack of the
    /// old operand plane is gone.  FC weights need no prepack (the dense
    /// row-major matrix IS the GEMM layout); their jobs alias the param
    /// allocation directly.
    conv_packs: Vec<Option<Arc<Vec<f32>>>>,
    /// Per-layer count of weight-pack events (shared across clones so the
    /// zero-copy proof tests can pin "exactly one pack per layer per
    /// network lifetime").
    pack_counts: Arc<Vec<AtomicU64>>,
}

/// Executor hooks for all the matrix work of a forward pass — CONV GEMMs,
/// FC GEMMs, and im2col lowering.  The default methods run natively on the
/// calling thread (the "ARM cores" baseline of paper §3.1.4); the runtime
/// plugs in `rt::PoolRouter`, which emits every class as jobs on the
/// shared heterogeneous accelerator pool.
pub trait MatExec {
    /// CONV GEMM over **packed** operand panels: `a_tiles` is the weight
    /// prepack ([`Network::conv_pack`], (rows·K,TS,TS)), `b_tiles` the
    /// packed im2col panels from [`MatExec::pack_cols`] ((cols·K,TS,TS)).
    /// Produces the dense C (M×P).  Operands arrive as views — an
    /// executor slices per-job windows out of them without copying.
    fn conv_gemm(
        &self,
        layer_idx: usize,
        grid: TileGrid,
        a_tiles: OperandView,
        b_tiles: OperandView,
    ) -> Vec<f32>;

    /// Pack a CONV layer's dense im2col matrix (N×P) into the blocked
    /// (cols·K,TS,TS) B layout.  The default packs into a fresh buffer;
    /// the pooled executor overrides this to pack into the frame arena so
    /// the layer's tile jobs alias frame-owned memory.
    fn pack_cols(&self, layer_idx: usize, grid: &TileGrid, col: &[f32]) -> OperandView {
        let _ = layer_idx;
        OperandView::from(grid.pack_b_tiles(col))
    }

    /// Pack a micro-batch's activation columns into the row-major (IN,B)
    /// fused-FC operand ([`crate::mm::job::pack_fc_columns`] layout).  The
    /// pooled executor overrides this to pack into the frame arena.
    fn pack_fc_cols(&self, layer_idx: usize, cols: &[&[f32]]) -> OperandView {
        let _ = layer_idx;
        OperandView::from(crate::mm::job::pack_fc_columns(cols))
    }

    /// FC GEMM: y(M) = W(M×N)·x(N).  Bias and activation are applied by
    /// the caller.  `w` is a view aliasing the network's weight param.
    fn fc_gemm(
        &self,
        layer_idx: usize,
        out_n: usize,
        in_n: usize,
        w: OperandView,
        x: OperandView,
    ) -> Vec<f32> {
        let _ = layer_idx;
        let mut y = vec![0.0f32; out_n];
        crate::mm::gemm::gemm_blocked_into(&w, &x, &mut y, out_n, in_n, 1);
        y
    }

    /// Fused batched FC GEMM: C(M,B) = W(M×N)·X(N,B), where `xb` packs one
    /// activation column per request ([`MatExec::pack_fc_cols`]).
    /// Bias and activation are applied per request by the caller.  The
    /// default runs the native kernel; the pooled executor emits one
    /// [`crate::mm::JobClass::FcGemmBatch`] job for the whole batch.
    fn fc_gemm_batch(
        &self,
        layer_idx: usize,
        out_n: usize,
        in_n: usize,
        batch: usize,
        w: OperandView,
        xb: OperandView,
    ) -> Vec<f32> {
        let _ = layer_idx;
        let mut c = vec![0.0f32; out_n * batch];
        crate::mm::gemm::gemm_blocked_into(&w, &xb, &mut c, out_n, in_n, batch);
        c
    }

    /// im2col lowering of a CONV layer's input.  Takes the activation by
    /// value: a pooled executor moves the buffer into a shared job
    /// operand instead of copying it.
    fn im2col_lower(
        &self,
        layer_idx: usize,
        input: Tensor,
        size: usize,
        stride: usize,
        pad: usize,
    ) -> Tensor {
        let _ = layer_idx;
        im2col(&input, size, stride, pad)
    }

    /// Can this executor run the int8 quantized job classes?  The native
    /// executors always can; the pooled executor answers from its
    /// clusters' accept masks — when no member claims the Q8 capability
    /// bits, the quantized forward
    /// ([`crate::nn::quant::QuantizedNetwork`]) falls back to the
    /// dequantized f32 classes instead of forcing inline execution.
    fn supports_q8(&self) -> bool {
        true
    }

    /// Freeze a freshly quantized i8 activation plane into
    /// executor-owned storage and return a view over it.  The default
    /// wraps it in a private `Arc`; the pooled executor adopts it into
    /// the frame arena so Q8 jobs alias frame-owned memory.
    fn adopt_q8_plane(&self, layer_idx: usize, codes: Vec<i8>) -> OperandView<i8> {
        let _ = layer_idx;
        OperandView::from(codes)
    }

    /// Quantized CONV GEMM over packed i8 operand panels — the Q8 twin of
    /// [`MatExec::conv_gemm`].  `scale` = s_w·s_x is applied once per
    /// output tile, after the exact i32 accumulation.
    fn conv_gemm_q8(
        &self,
        layer_idx: usize,
        grid: TileGrid,
        a_tiles: OperandView<i8>,
        b_tiles: OperandView<i8>,
        scale: f32,
    ) -> Vec<f32> {
        let _ = layer_idx;
        let panel = grid.panel_elems();
        let mut c = vec![0.0f32; grid.m * grid.p];
        for (t1, t2) in grid.tiles() {
            let tile = crate::mm::tile::job_mm_q8_native(
                &a_tiles[t1 * panel..(t1 + 1) * panel],
                &b_tiles[t2 * panel..(t2 + 1) * panel],
                grid.k_tiles(),
                grid.ts,
                scale,
            );
            grid.scatter_c(&mut c, t1, t2, &tile);
        }
        c
    }

    /// Quantized FC GEMM: y(M) = scale · (Wq(M×N)·xq(N)) — the Q8 twin of
    /// [`MatExec::fc_gemm`].  Bias and activation stay f32 and are
    /// applied by the caller.
    fn fc_gemm_q8(
        &self,
        layer_idx: usize,
        out_n: usize,
        in_n: usize,
        w: OperandView<i8>,
        x: OperandView<i8>,
        scale: f32,
    ) -> Vec<f32> {
        let _ = layer_idx;
        let mut acc = vec![0i32; out_n];
        crate::mm::gemm::gemm_q8_blocked_into(&w, &x, &mut acc, out_n, in_n, 1);
        acc.iter().map(|&v| v as f32 * scale).collect()
    }

    /// Quantized fused batched FC GEMM — the Q8 twin of
    /// [`MatExec::fc_gemm_batch`] over a column-packed (IN,B) i8 operand
    /// ([`crate::mm::job::pack_fc_columns_q8`]).
    #[allow(clippy::too_many_arguments)]
    fn fc_gemm_batch_q8(
        &self,
        layer_idx: usize,
        out_n: usize,
        in_n: usize,
        batch: usize,
        w: OperandView<i8>,
        xb: OperandView<i8>,
        scale: f32,
    ) -> Vec<f32> {
        let _ = layer_idx;
        let mut acc = vec![0i32; out_n * batch];
        crate::mm::gemm::gemm_q8_blocked_into(&w, &xb, &mut acc, out_n, in_n, batch);
        acc.iter().map(|&v| v as f32 * scale).collect()
    }
}

/// The all-native executor ([`Network::forward_reference`]'s backend).
/// Runs the same per-tile job kernel over the same packed panels as the
/// pool path, so the reference forward is bit-identical to pooled
/// execution **by construction** — they share every FLOP's accumulation
/// order.
pub struct NativeExec;

impl MatExec for NativeExec {
    fn conv_gemm(
        &self,
        _layer_idx: usize,
        grid: TileGrid,
        a_tiles: OperandView,
        b_tiles: OperandView,
    ) -> Vec<f32> {
        let panel = grid.panel_elems();
        let mut c = vec![0.0f32; grid.m * grid.p];
        for (t1, t2) in grid.tiles() {
            let tile = crate::mm::tile::job_mm_native(
                &a_tiles[t1 * panel..(t1 + 1) * panel],
                &b_tiles[t2 * panel..(t2 + 1) * panel],
                grid.k_tiles(),
                grid.ts,
            );
            grid.scatter_c(&mut c, t1, t2, &tile);
        }
        c
    }
}

/// Adapter treating a bare CONV-GEMM closure as a full executor (FC GEMMs
/// and im2col run natively) — keeps simple call sites and tests tidy.
pub struct GemmExecFn<F>(pub F);

impl<F> MatExec for GemmExecFn<F>
where
    F: Fn(usize, TileGrid, OperandView, OperandView) -> Vec<f32>,
{
    fn conv_gemm(
        &self,
        layer_idx: usize,
        grid: TileGrid,
        a_tiles: OperandView,
        b_tiles: OperandView,
    ) -> Vec<f32> {
        (self.0)(layer_idx, grid, a_tiles, b_tiles)
    }
}

impl Network {
    /// Build with deterministic parameters (tile size for job geometry).
    pub fn new(config: NetConfig, tile_size: usize) -> Result<Network> {
        let shapes = infer_shapes(&config)?;
        let params = init_params(&config, &shapes);
        let weight_arcs = config
            .layers
            .iter()
            .enumerate()
            .map(|(idx, layer)| {
                matches!(
                    layer,
                    LayerSpec::Conv { .. } | LayerSpec::Connected { .. }
                )
                .then(|| {
                    params
                        .iter()
                        .find(|p| p.layer == idx && p.name == "weights")
                        .expect("conv/fc layer has weights")
                        .shared()
                })
            })
            .collect();
        let n_layers = config.layers.len();
        let mut net = Network {
            config,
            params,
            shapes,
            tile_size,
            weight_arcs,
            conv_packs: vec![None; n_layers],
            pack_counts: Arc::new((0..n_layers).map(|_| AtomicU64::new(0)).collect()),
        };
        // Pack every CONV layer's weights into the blocked job layout
        // exactly ONCE, here at load.  The per-frame hot path only ever
        // slices views out of these buffers.
        for info in net.conv_infos() {
            let packed = info.grid.pack_a_tiles(&net.weights_arc(info.layer_idx));
            net.conv_packs[info.layer_idx] = Some(Arc::new(packed));
            net.pack_counts[info.layer_idx].fetch_add(1, Ordering::Relaxed);
        }
        Ok(net)
    }

    /// Shared GEMM weight operand of a CONV/FC layer (cheap Arc clone;
    /// panics for layers without weights).
    pub fn weights_arc(&self, layer: usize) -> Arc<Vec<f32>> {
        Arc::clone(
            self.weight_arcs[layer]
                .as_ref()
                .expect("layer has GEMM weights"),
        )
    }

    /// View of a CONV layer's load-time weight prepack — the blocked
    /// (rows·K,TS,TS) A operand every frame's tile jobs alias.  Cheap
    /// (refcount bump); panics for layers without a CONV prepack.
    pub fn conv_pack(&self, layer: usize) -> OperandView {
        OperandView::full(Arc::clone(
            self.conv_packs[layer]
                .as_ref()
                .expect("conv layer has a weight prepack"),
        ))
    }

    /// How many times `layer`'s weights have been packed into the blocked
    /// layout over this network's lifetime.  The zero-copy contract pins
    /// this at exactly 1 for CONV layers (the load-time prepack) and 0
    /// for everything else — nothing on the dispatch path re-packs
    /// weights.
    pub fn weight_pack_count(&self, layer: usize) -> u64 {
        self.pack_counts[layer].load(Ordering::Relaxed)
    }

    pub fn tile_size(&self) -> usize {
        self.tile_size
    }

    pub fn input_shape(&self) -> (usize, usize, usize) {
        self.config.input_shape()
    }

    /// Deterministic synthetic input frame in [0,1) — matches
    /// `python model.make_input`.
    pub fn make_input(&self, frame: u64) -> Tensor {
        let (c, h, w) = self.input_shape();
        let n = c * h * w;
        let base = rng::fill_tensor(
            &self.config.name,
            1_000_000 + frame as usize,
            "input",
            n,
            1.0,
        );
        Tensor::from_vec(&[c, h, w], base.iter().map(|v| v + 0.5).collect())
    }

    /// Parameters of one layer by name.
    pub fn layer_param(&self, layer: usize, name: &str) -> Option<&Param> {
        self.params
            .iter()
            .find(|p| p.layer == layer && p.name == name)
    }

    /// CONV layer descriptors in network order.
    pub fn conv_infos(&self) -> Vec<ConvLayerInfo> {
        let mut infos = Vec::new();
        let mut cur = Shape::Chw(self.config.channels, self.config.height, self.config.width);
        let mut conv_idx = 0;
        for (idx, layer) in self.config.layers.iter().enumerate() {
            if let LayerSpec::Conv {
                filters,
                size,
                stride,
                pad,
                activation,
            } = layer
            {
                let (c, h, w) = match cur {
                    Shape::Chw(c, h, w) => (c, h, w),
                    Shape::Flat(_) => unreachable!("conv after flatten rejected at build"),
                };
                let (oh, ow) = super::conv_out_hw(h, w, *size, *stride, *pad);
                infos.push(ConvLayerInfo {
                    layer_idx: idx,
                    conv_idx,
                    filters: *filters,
                    size: *size,
                    stride: *stride,
                    pad: *pad,
                    activation: *activation,
                    in_shape: (c, h, w),
                    out_shape: (*filters, oh, ow),
                    grid: TileGrid::new(*filters, c * size * size, oh * ow, self.tile_size),
                });
                conv_idx += 1;
            }
            cur = self.shapes[idx];
        }
        infos
    }

    /// Total MAC-ops·2 per frame in millions (paper GOP accounting),
    /// mirrors `python model.model_mops`.
    pub fn mops(&self) -> f64 {
        let mut total = 0f64;
        let mut cur = Shape::Chw(self.config.channels, self.config.height, self.config.width);
        for (idx, layer) in self.config.layers.iter().enumerate() {
            match layer {
                LayerSpec::Conv {
                    filters, size, ..
                } => {
                    if let Shape::Chw(c, _, _) = cur {
                        if let Shape::Chw(_, oh, ow) = self.shapes[idx] {
                            total +=
                                2.0 * (*filters * oh * ow * c * size * size) as f64;
                        }
                    }
                }
                LayerSpec::MaxPool { size, .. } | LayerSpec::AvgPool { size, .. } => {
                    if let Shape::Chw(c, oh, ow) = self.shapes[idx] {
                        total += (c * oh * ow * size * size) as f64;
                    }
                }
                LayerSpec::Connected { output, .. } => {
                    total += 2.0 * (cur.len() * output) as f64;
                }
                LayerSpec::BatchNorm => total += 2.0 * cur.len() as f64,
                _ => {}
            }
            cur = self.shapes[idx];
        }
        total / 1e6
    }

    /// Pool jobs one frame generates per [`JobClass`] when all matrix work
    /// is routed through the accelerator pool (`rt::PoolRouter`): one job
    /// per CONV output tile, one FC-GEMM job per connected layer, one
    /// im2col job per CONV layer.
    pub fn pool_job_profile(&self) -> [usize; JobClass::COUNT] {
        let mut profile = [0usize; JobClass::COUNT];
        let convs = self.conv_infos();
        profile[JobClass::ConvTile.index()] =
            convs.iter().map(|ci| ci.grid.num_jobs()).sum();
        profile[JobClass::Im2col.index()] = convs.len();
        profile[JobClass::FcGemm.index()] = self.fc_layer_count();
        profile
    }

    /// Pool jobs a B-request micro-batch generates per [`JobClass`] on the
    /// fused path ([`Network::forward_batch_with`]): the CONV front-end
    /// scales per frame, while each FC layer emits exactly **one**
    /// [`JobClass::FcGemmBatch`] job for the whole batch.
    pub fn pool_job_profile_batched(&self, batch: usize) -> [usize; JobClass::COUNT] {
        let mut profile = self.pool_job_profile();
        profile[JobClass::ConvTile.index()] *= batch;
        profile[JobClass::Im2col.index()] *= batch;
        profile[JobClass::FcGemm.index()] = 0;
        profile[JobClass::FcGemmBatch.index()] = self.fc_layer_count();
        profile
    }

    /// Number of fully-connected layers.
    pub fn fc_layer_count(&self) -> usize {
        self.config
            .layers
            .iter()
            .filter(|l| matches!(l, LayerSpec::Connected { .. }))
            .count()
    }

    /// Reference forward pass — sequential, CPU-only (the "original
    /// single-threaded Darknet" baseline, functionally).
    pub fn forward_reference(&self, x: &Tensor) -> Tensor {
        self.forward_with(x, &NativeExec)
    }

    /// Forward pass with a pluggable matrix-work executor.
    pub fn forward_with(&self, x: &Tensor, exec: &dyn MatExec) -> Tensor {
        let (c, h, w) = self.input_shape();
        assert_eq!(x.shape(), &[c, h, w], "input shape mismatch");
        let mut cur = x.clone();
        for (idx, layer) in self.config.layers.iter().enumerate() {
            cur = self.forward_layer(idx, layer, cur, exec);
        }
        cur
    }

    /// Batched forward pass: the CONV front-end (im2col + tile GEMMs +
    /// pooling/BN) runs per frame, but every FC layer is **fused across
    /// the batch** into one (OUT,IN)×(IN,B) GEMM via
    /// [`MatExec::fc_gemm_batch`] — one pool job (and one big-NEON
    /// fan-out) per FC layer per micro-batch instead of per request.
    /// Outputs are bit-identical to running [`Network::forward_with`] per
    /// sample: the fused kernel accumulates each output element in the
    /// per-sample order.
    pub fn forward_batch_with(&self, xs: &[Tensor], exec: &dyn MatExec) -> Vec<Tensor> {
        let (c, h, w) = self.input_shape();
        for x in xs {
            assert_eq!(x.shape(), &[c, h, w], "input shape mismatch");
        }
        let mut cur: Vec<Tensor> = xs.to_vec();
        for (idx, layer) in self.config.layers.iter().enumerate() {
            cur = self.forward_layer_batch(idx, layer, cur, exec);
        }
        cur
    }

    /// Execute a single layer over a micro-batch of activations.
    /// `Connected` layers fuse the whole batch into one batched FC GEMM;
    /// every other layer runs per item through [`Network::forward_layer`]
    /// (the CONV front-end stays per-frame).  The serving pipelines call
    /// this per layer stage; [`Network::forward_batch_with`] folds it over
    /// the whole network.
    pub fn forward_layer_batch(
        &self,
        idx: usize,
        layer: &LayerSpec,
        inputs: Vec<Tensor>,
        exec: &dyn MatExec,
    ) -> Vec<Tensor> {
        let LayerSpec::Connected { activation, .. } = layer else {
            return inputs
                .into_iter()
                .map(|x| self.forward_layer(idx, layer, x, exec))
                .collect();
        };
        if inputs.is_empty() {
            return inputs;
        }
        let w = self.layer_param(idx, "weights").expect("fc weights");
        let b = self.layer_param(idx, "bias").expect("fc bias");
        let (out_n, in_n) = (w.shape()[0], w.shape()[1]);
        let batch = inputs.len();
        let cols: Vec<&[f32]> = inputs
            .iter()
            .map(|t| {
                assert_eq!(t.len(), in_n, "input length mismatch");
                t.data()
            })
            .collect();
        let xb = exec.pack_fc_cols(idx, &cols);
        let c = exec.fc_gemm_batch(
            idx,
            out_n,
            in_n,
            batch,
            OperandView::full(self.weights_arc(idx)),
            xb,
        );
        crate::mm::job::unpack_fc_columns(&c, out_n, batch)
            .into_iter()
            .map(|mut y| {
                for (v, bv) in y.iter_mut().zip(b.data()) {
                    *v = activation.apply(*v + *bv);
                }
                Tensor::from_vec(&[out_n], y)
            })
            .collect()
    }

    /// Execute a single layer (used by both the reference forward and the
    /// pipeline stages, so layer semantics exist exactly once).  All
    /// matrix work — im2col, the CONV GEMM, the FC GEMM — goes through
    /// `exec`, so a pooled executor dispatches it to the accelerators.
    pub fn forward_layer(
        &self,
        idx: usize,
        layer: &LayerSpec,
        input: Tensor,
        exec: &dyn MatExec,
    ) -> Tensor {
        match layer {
            LayerSpec::Conv {
                filters,
                size,
                stride,
                pad,
                activation,
            } => {
                let (cin, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
                let (oh, ow) = super::conv_out_hw(h, w, *size, *stride, *pad);
                // Preprocessing (paper §3.1.4), routed through the
                // executor so the pool can run it as an im2col job (the
                // activation buffer moves into the job — no copy).
                let col = exec.im2col_lower(idx, input, *size, *stride, *pad);
                let grid = TileGrid::new(
                    *filters,
                    cin * size * size,
                    oh * ow,
                    self.tile_size,
                );
                // B packs once per layer per frame (into the executor's
                // arena on the pooled path); A is the load-time prepack.
                let b_tiles = exec.pack_cols(idx, &grid, col.data());
                let c_mat = exec.conv_gemm(idx, grid, self.conv_pack(idx), b_tiles);
                let bias = self.layer_param(idx, "bias").expect("conv bias");
                let mut out = Tensor::from_vec(&[*filters, oh, ow], c_mat);
                for o in 0..*filters {
                    let plane = &mut out.data_mut()[o * oh * ow..(o + 1) * oh * ow];
                    let bv = bias.data()[o];
                    for v in plane {
                        *v += bv;
                    }
                }
                conv::activate(&mut out, *activation);
                out
            }
            LayerSpec::MaxPool { size, stride } => pool::maxpool(&input, *size, *stride),
            LayerSpec::AvgPool { size, stride } => pool::avgpool(&input, *size, *stride),
            LayerSpec::Connected { activation, .. } => {
                let w = self.layer_param(idx, "weights").expect("fc weights");
                let b = self.layer_param(idx, "bias").expect("fc bias");
                let (out_n, in_n) = (w.shape()[0], w.shape()[1]);
                assert_eq!(input.len(), in_n, "input length mismatch");
                let mut out = exec.fc_gemm(
                    idx,
                    out_n,
                    in_n,
                    OperandView::full(self.weights_arc(idx)),
                    OperandView::from(input.into_vec()),
                );
                for (v, bv) in out.iter_mut().zip(b.data()) {
                    *v = activation.apply(*v + *bv);
                }
                let n = out.len();
                Tensor::from_vec(&[n], out)
            }
            LayerSpec::BatchNorm => {
                let g = self.layer_param(idx, "gamma").expect("bn gamma");
                let b = self.layer_param(idx, "beta").expect("bn beta");
                let m = self.layer_param(idx, "mean").expect("bn mean");
                let v = self.layer_param(idx, "var").expect("bn var");
                batchnorm(&input, g.data(), b.data(), m.data(), v.data())
            }
            LayerSpec::Dropout { .. } => input, // inference no-op
            LayerSpec::Softmax => {
                let n = input.len();
                let mut flat = input.into_vec();
                softmax::softmax(&mut flat);
                Tensor::from_vec(&[n], flat)
            }
        }
    }
}

/// Shape inference (rejects invalid topologies, e.g. conv after flatten).
pub fn infer_shapes(config: &NetConfig) -> Result<Vec<Shape>> {
    let mut shapes = Vec::with_capacity(config.layers.len());
    let mut cur = Shape::Chw(config.channels, config.height, config.width);
    for (idx, layer) in config.layers.iter().enumerate() {
        cur = match layer {
            LayerSpec::Conv {
                filters,
                size,
                stride,
                pad,
                ..
            } => match cur {
                Shape::Chw(_, h, w) => {
                    if h + 2 * pad < *size || w + 2 * pad < *size {
                        bail!("{}: layer {idx}: kernel larger than input", config.name);
                    }
                    let (oh, ow) = super::conv_out_hw(h, w, *size, *stride, *pad);
                    Shape::Chw(*filters, oh, ow)
                }
                Shape::Flat(_) => bail!("{}: conv layer {idx} after flatten", config.name),
            },
            LayerSpec::MaxPool { size, stride } | LayerSpec::AvgPool { size, stride } => {
                match cur {
                    Shape::Chw(c, h, w) => {
                        if h < *size || w < *size {
                            bail!("{}: layer {idx}: pool larger than input", config.name);
                        }
                        let (oh, ow) = super::pool_out_hw(h, w, *size, *stride);
                        Shape::Chw(c, oh, ow)
                    }
                    Shape::Flat(_) => bail!("{}: pool layer {idx} after flatten", config.name),
                }
            }
            LayerSpec::Connected { output, .. } => Shape::Flat(*output),
            LayerSpec::BatchNorm | LayerSpec::Dropout { .. } | LayerSpec::Softmax => cur,
        };
        shapes.push(cur);
    }
    Ok(shapes)
}

/// Deterministic parameter init — bit-identical to python `init_params`.
fn init_params(config: &NetConfig, shapes: &[Shape]) -> Vec<Param> {
    let mut out = Vec::new();
    let mut cur = Shape::Chw(config.channels, config.height, config.width);
    let model = config.name.as_str();
    for (idx, layer) in config.layers.iter().enumerate() {
        match layer {
            LayerSpec::Conv { filters, size, .. } => {
                let c = match cur {
                    Shape::Chw(c, _, _) => c,
                    Shape::Flat(_) => unreachable!(),
                };
                let fan_in = c * size * size;
                let scale = (2.0f64 / fan_in as f64).sqrt() as f32;
                let n = filters * fan_in;
                let base = rng::fill_tensor(model, idx, "weights", n, 1.0);
                // GEMM view (OC, C·K²) — same row-major layout as the
                // python (OC,C,K,K) array.
                out.push(Param::new(
                    idx,
                    "weights",
                    &[*filters, fan_in],
                    base.iter().map(|v| v * scale).collect(),
                ));
                let bias = rng::fill_tensor(model, idx, "bias", *filters, 1.0);
                out.push(Param::new(
                    idx,
                    "bias",
                    &[*filters],
                    bias.iter().map(|v| v * 0.1).collect(),
                ));
            }
            LayerSpec::Connected { output, .. } => {
                let n_in = cur.len();
                let scale = (2.0f64 / n_in as f64).sqrt() as f32;
                let base = rng::fill_tensor(model, idx, "weights", output * n_in, 1.0);
                out.push(Param::new(
                    idx,
                    "weights",
                    &[*output, n_in],
                    base.iter().map(|v| v * scale).collect(),
                ));
                let bias = rng::fill_tensor(model, idx, "bias", *output, 1.0);
                out.push(Param::new(
                    idx,
                    "bias",
                    &[*output],
                    bias.iter().map(|v| v * 0.1).collect(),
                ));
            }
            LayerSpec::BatchNorm => {
                let c = match cur {
                    Shape::Chw(c, _, _) => c,
                    Shape::Flat(n) => n,
                };
                let mk = |name: &'static str, f: &dyn Fn(f32) -> f32| {
                    Param::new(
                        idx,
                        name,
                        &[c],
                        rng::fill_tensor(model, idx, name, c, 1.0)
                            .iter()
                            .map(|v| f(*v))
                            .collect(),
                    )
                };
                out.push(mk("gamma", &|u| 1.0 + 0.1 * u));
                out.push(mk("beta", &|u| 0.1 * u));
                out.push(mk("mean", &|u| 0.1 * u));
                out.push(mk("var", &|u| 1.0 + 0.5 * (u + 0.5)));
            }
            _ => {}
        }
        cur = shapes[idx];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::zoo;

    fn mk(name: &str) -> Network {
        Network::new(zoo::load(name).unwrap(), 32).unwrap()
    }

    #[test]
    fn shapes_end_in_ten_classes() {
        for name in zoo::ZOO {
            let net = mk(name);
            assert_eq!(*net.shapes.last().unwrap(), Shape::Flat(10), "{name}");
        }
    }

    #[test]
    fn mnist_shape_walk() {
        let net = mk("mnist");
        assert_eq!(net.shapes[0], Shape::Chw(32, 28, 28)); // conv 5x5 pad2
        assert_eq!(net.shapes[1], Shape::Chw(32, 14, 14)); // pool
        assert_eq!(net.shapes[2], Shape::Chw(64, 14, 14)); // conv
        assert_eq!(net.shapes[3], Shape::Chw(64, 7, 7)); // pool
        assert_eq!(net.shapes[4], Shape::Flat(128));
        assert_eq!(net.shapes[5], Shape::Flat(10));
    }

    #[test]
    fn forward_is_probability_vector() {
        for name in ["mnist", "mpcnn", "cifar_full"] {
            let net = mk(name);
            let x = net.make_input(0);
            let y = net.forward_reference(&x);
            assert_eq!(y.shape(), &[10], "{name}");
            let sum: f32 = y.data().iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "{name}: sum={sum}");
            assert!(y.data().iter().all(|&v| v >= 0.0), "{name}");
        }
    }

    #[test]
    fn conv_infos_match_python_k_tiles() {
        // K values pinned from python: see DESIGN.md §5 / aot manifest.
        let expect: &[(&str, &[usize])] = &[
            ("cifar_darknet", &[1, 9, 18, 4]),
            ("cifar_alex", &[3, 25, 14]),
            ("cifar_alex_plus", &[3, 50, 18]),
            ("cifar_full", &[3, 25, 25]),
            ("mnist", &[1, 25]),
            ("svhn", &[3, 25, 14]),
            ("mpcnn", &[1, 13, 9]),
        ];
        for (name, ks) in expect {
            let net = mk(name);
            let got: Vec<usize> = net.conv_infos().iter().map(|i| i.grid.k_tiles()).collect();
            assert_eq!(&got, ks, "{name}");
        }
    }

    #[test]
    fn mops_in_expected_band() {
        // DESIGN.md §5 band: workloads sized to the paper's GOP/frame.
        let expect = [
            ("cifar_darknet", 21.0),
            ("cifar_alex", 28.2),
            ("cifar_alex_plus", 67.6),
            ("cifar_full", 24.7),
            ("mnist", 22.2),
            ("svhn", 28.2),
            ("mpcnn", 9.3),
        ];
        for (name, want) in expect {
            let got = mk(name).mops();
            assert!(
                (got - want).abs() / want < 0.02,
                "{name}: mops {got} vs {want}"
            );
        }
    }

    #[test]
    fn params_deterministic() {
        let a = mk("mnist");
        let b = mk("mnist");
        assert_eq!(a.params.len(), b.params.len());
        for (pa, pb) in a.params.iter().zip(&b.params) {
            assert_eq!(pa.shape(), pb.shape());
            assert_eq!(pa.data(), pb.data());
        }
    }

    #[test]
    fn weight_arcs_share_param_allocations() {
        // The GEMM-operand cache and the params point at ONE allocation
        // per weight matrix — no duplication per loaded network.
        let net = mk("cifar_full");
        for (idx, layer) in net.config.layers.iter().enumerate() {
            if matches!(layer, LayerSpec::Conv { .. } | LayerSpec::Connected { .. }) {
                let p = net.layer_param(idx, "weights").expect("weights");
                assert!(
                    Arc::ptr_eq(&p.shared(), &net.weights_arc(idx)),
                    "layer {idx}: weights duplicated"
                );
            }
        }
    }

    #[test]
    fn forward_with_custom_executor_used() {
        use std::sync::atomic::AtomicUsize;
        let net = mk("mnist");
        let calls = AtomicUsize::new(0);
        let x = net.make_input(0);
        let exec = GemmExecFn(
            |idx: usize, grid: TileGrid, a: OperandView, b: OperandView| {
                calls.fetch_add(1, Ordering::SeqCst);
                NativeExec.conv_gemm(idx, grid, a, b)
            },
        );
        let y = net.forward_with(&x, &exec);
        assert_eq!(calls.load(Ordering::SeqCst), 2); // mnist has 2 convs
        let want = net.forward_reference(&x);
        assert!(y.allclose(&want, 1e-6, 1e-6));
    }

    /// The load-time prepack contract: every CONV layer's weights are in
    /// the blocked layout exactly once per network lifetime, the packs
    /// match a fresh `pack_a_tiles` of the dense weights, and running
    /// frames does not re-pack anything.
    #[test]
    fn conv_weights_prepacked_once_at_load() {
        let net = mk("mnist");
        for (idx, layer) in net.config.layers.iter().enumerate() {
            match layer {
                LayerSpec::Conv { .. } => {
                    assert_eq!(net.weight_pack_count(idx), 1, "layer {idx}")
                }
                _ => assert_eq!(net.weight_pack_count(idx), 0, "layer {idx}"),
            }
        }
        for info in net.conv_infos() {
            let pack = net.conv_pack(info.layer_idx);
            assert_eq!(pack.len(), info.grid.rows() * info.grid.panel_elems());
            let fresh = info.grid.pack_a_tiles(&net.weights_arc(info.layer_idx));
            assert_eq!(pack.as_slice(), &fresh[..], "layer {}", info.layer_idx);
            // Repeated accessors alias ONE allocation.
            assert!(Arc::ptr_eq(
                pack.buffer(),
                net.conv_pack(info.layer_idx).buffer()
            ));
        }
        // Forwarding frames must not trigger any further weight packs.
        for f in 0..3 {
            let _ = net.forward_reference(&net.make_input(f));
        }
        for info in net.conv_infos() {
            assert_eq!(net.weight_pack_count(info.layer_idx), 1);
        }
    }

    #[test]
    fn pool_job_profile_counts_all_classes() {
        let net = mk("mnist");
        let profile = net.pool_job_profile();
        let conv_jobs: usize = net.conv_infos().iter().map(|ci| ci.grid.num_jobs()).sum();
        assert_eq!(profile[JobClass::ConvTile.index()], conv_jobs);
        assert_eq!(profile[JobClass::Im2col.index()], 2); // two CONV layers
        assert_eq!(profile[JobClass::FcGemm.index()], 2); // two FC layers
        assert_eq!(profile[JobClass::FcGemmBatch.index()], 0); // per-sample path

        // The fused profile scales the CONV front-end per frame but emits
        // ONE batched-FC job per FC layer regardless of batch size.
        let batched = net.pool_job_profile_batched(4);
        assert_eq!(batched[JobClass::ConvTile.index()], conv_jobs * 4);
        assert_eq!(batched[JobClass::Im2col.index()], 2 * 4);
        assert_eq!(batched[JobClass::FcGemm.index()], 0);
        assert_eq!(batched[JobClass::FcGemmBatch.index()], 2);
        assert_eq!(net.fc_layer_count(), 2);
    }

    /// Zoo-wide fused-path equivalence: `forward_batch_with` must match
    /// the per-sample reference forward on every model — and because the
    /// fused FC kernel accumulates in per-sample order, bit-exactly.
    #[test]
    fn forward_batch_matches_reference_across_zoo() {
        for name in zoo::ZOO {
            let net = mk(name);
            let xs: Vec<Tensor> = (0..3).map(|f| net.make_input(f)).collect();
            let got = net.forward_batch_with(&xs, &NativeExec);
            assert_eq!(got.len(), xs.len(), "{name}");
            for (j, x) in xs.iter().enumerate() {
                let want = net.forward_reference(x);
                assert!(
                    got[j].allclose(&want, 1e-6, 1e-6),
                    "{name} item {j}: {}",
                    got[j].max_abs_diff(&want)
                );
                assert_eq!(got[j].data(), want.data(), "{name} item {j} not bit-exact");
            }
        }
    }

    #[test]
    fn forward_layer_batch_falls_back_per_item_on_non_fc() {
        let net = mk("mnist");
        let xs: Vec<Tensor> = (0..2).map(|f| net.make_input(f)).collect();
        let layer = net.config.layers[0].clone();
        let fused = net.forward_layer_batch(0, &layer, xs.clone(), &NativeExec);
        for (x, got) in xs.into_iter().zip(fused) {
            let want = net.forward_layer(0, &layer, x, &NativeExec);
            assert_eq!(got.data(), want.data());
        }
    }

    #[test]
    fn invalid_topologies_rejected() {
        let cfg = crate::config::NetConfig::parse(
            "bad",
            "[net]\nheight=4\nwidth=4\nchannels=1\n[connected]\noutput=5\n[convolutional]\nfilters=2\nsize=3\n",
        )
        .unwrap();
        assert!(Network::new(cfg, 32).is_err());

        let cfg = crate::config::NetConfig::parse(
            "bad2",
            "[net]\nheight=2\nwidth=2\nchannels=1\n[maxpool]\nsize=4\n",
        )
        .unwrap();
        assert!(Network::new(cfg, 32).is_err());
    }
}
