//! Numerically-stable softmax (final classifier layer).

/// Softmax over a flat vector, in place.
pub fn softmax(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let max = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        softmax(&mut x);
        let sum: f32 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(x.windows(2).all(|w| w[0] < w[1])); // monotone preserved
    }

    #[test]
    fn shift_invariant() {
        let mut a = vec![1.0, 2.0, 3.0];
        let mut b = vec![101.0, 102.0, 103.0];
        softmax(&mut a);
        softmax(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn handles_large_values_without_overflow() {
        let mut x = vec![1000.0, 1000.0];
        softmax(&mut x);
        assert!((x[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn empty_is_noop() {
        let mut x: Vec<f32> = vec![];
        softmax(&mut x);
        assert!(x.is_empty());
    }
}
