//! The zero-copy operand plane: shared-buffer operand views and the
//! per-frame bump arena the runtime packs operands into.
//!
//! Every pool [`Job`](crate::mm::Job) used to own `Vec<f32>` operands —
//! CONV tiles re-packed a (K,TS,TS) fetch set per job, fused FC batches
//! cloned their activation columns, and weights were re-packed on every
//! dispatch.  An [`OperandView`] replaces the owned buffers: an `Arc`
//! backing allocation plus an offset/length window into it.  Cloning a
//! view is a refcount bump; slicing is arithmetic; the bytes move exactly
//! once — when a layout transform packs them into a fresh buffer (counted
//! by [`copied_bytes`]/[`copy_events`]) or when the remote `wire` codec
//! serializes a view for shipping.
//!
//! The plane is dtype-aware: `OperandView<T>` is generic over the
//! [`OperandScalar`] element type (`f32` for the reference path, `i8` for
//! quantized operand planes, `i32` for wide accumulators), defaulting to
//! `f32` so the pre-quantization surface reads unchanged.  Where
//! heterogeneous dtypes must share one container — the remote shard's
//! operand cache — the erased [`Plane`] enum tags the backing allocation
//! with its dtype.
//!
//! A [`FrameArena`] owns the per-frame transient buffers (im2col columns,
//! packed B panels, fused FC column packs, quantized activation planes):
//! the frame executor allocates into it, jobs carry views that alias its
//! chunks, and the whole frame's working set is dropped at once when the
//! arena goes out of scope.  Load-time weight prepacks live on the
//! `Network` instead and are aliased by every frame's jobs for the
//! network's lifetime.

use crate::util::sync::{lock_clean, Mutex};
use std::any::Any;
use std::collections::HashMap;
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, Weak};

/// Process-wide layout-transform copy ledger: bytes that were actually
/// copied into a fresh buffer (tile packing, FC column packing).  Cheap
/// view clones and arena adoptions do NOT count — that is the point.
static COPIED_BYTES: AtomicU64 = AtomicU64::new(0);
static COPY_EVENTS: AtomicU64 = AtomicU64::new(0);

/// Record one layout-transform copy of `bytes` bytes.  Called by the
/// pack/extract helpers in `mm::tile` and `mm::job`; everything else in
/// the operand plane moves views, not bytes.
pub(crate) fn note_copy(bytes: usize) {
    COPIED_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
    COPY_EVENTS.fetch_add(1, Ordering::Relaxed);
}

/// Total bytes copied by operand layout transforms since process start.
pub fn copied_bytes() -> u64 {
    COPIED_BYTES.load(Ordering::Relaxed)
}

/// Total layout-transform copy events since process start.
pub fn copy_events() -> u64 {
    COPY_EVENTS.load(Ordering::Relaxed)
}

/// Element types an operand plane can carry.  The trait is deliberately
/// tiny: the plane moves and windows bytes, it never does arithmetic on
/// them — kernels downcast to concrete slices.
pub trait OperandScalar:
    Copy + Default + PartialEq + Send + Sync + std::fmt::Debug + 'static
{
    /// Size of one element on the wire (and in the cache byte ledger).
    const BYTES: usize;
    /// dtype label for debug output and ledger rows.
    const LABEL: &'static str;
}

impl OperandScalar for f32 {
    const BYTES: usize = 4;
    const LABEL: &'static str = "f32";
}

impl OperandScalar for i8 {
    const BYTES: usize = 1;
    const LABEL: &'static str = "i8";
}

impl OperandScalar for i32 {
    const BYTES: usize = 4;
    const LABEL: &'static str = "i32";
}

/// Content-addressed identity of a shared operand buffer: a per-process
/// origin nonce plus a monotone sequence number minted the first time a
/// buffer is keyed.  Two views over the same `Arc` allocation share a key;
/// a repack into a fresh allocation (a weight pack-generation bump, a new
/// frame arena chunk) gets a fresh key — which is exactly what lets a
/// remote shard cache packed fetch sets by identity and lets the client
/// detect "this slot now holds different bytes" without hashing them.
pub type OperandKey = (u64, u64);

struct KeyRegistry {
    origin: u64,
    next_seq: AtomicU64,
    /// Thin `Arc::as_ptr` address → (sequence, liveness witness).  The
    /// `Weak` guards against address reuse: an allocation dropped and
    /// replaced by a new one at the same address must NOT inherit the old
    /// key.  The witness is dtype-erased so one registry keys every
    /// operand dtype — an address can only belong to one live allocation
    /// at a time regardless of element type.
    by_ptr: Mutex<HashMap<usize, (u64, Weak<dyn Any + Send + Sync>)>>,
}

fn key_registry() -> &'static KeyRegistry {
    static REGISTRY: OnceLock<KeyRegistry> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        // A per-process random nonce (the std hash seed) so keys minted by
        // two different client processes never collide in one shard cache.
        use std::hash::{BuildHasher, Hasher};
        let mut h = std::collections::hash_map::RandomState::new().build_hasher();
        h.write_u64(0x6f70_6572_616e_6421);
        KeyRegistry {
            origin: h.finish(),
            next_seq: AtomicU64::new(1),
            by_ptr: Mutex::new(HashMap::new()),
        }
    })
}

/// Stable cache key of a shared operand buffer of any dtype.  Idempotent
/// per live allocation; process-wide, so every `RemoteShard` in this
/// process keys the same prepack identically and a shard dedupes across
/// connections.
pub fn operand_key<T: OperandScalar>(buf: &Arc<Vec<T>>) -> OperandKey {
    let reg = key_registry();
    let ptr = Arc::as_ptr(buf) as usize;
    let mut map = lock_clean(&reg.by_ptr);
    if let Some((seq, witness)) = map.get(&ptr) {
        if let Some(live) = witness.upgrade() {
            if Arc::as_ptr(&live) as *const () as usize == ptr {
                return (reg.origin, *seq);
            }
        }
    }
    // First sighting (or a dead entry's address was reused): mint fresh.
    let seq = reg.next_seq.fetch_add(1, Ordering::Relaxed);
    let erased: Arc<dyn Any + Send + Sync> = Arc::clone(buf) as Arc<dyn Any + Send + Sync>;
    map.insert(ptr, (seq, Arc::downgrade(&erased)));
    // Bound the map: dead entries whose address never gets reused would
    // otherwise accumulate for the process lifetime.
    if map.len() > 4096 {
        map.retain(|_, (_, w)| w.strong_count() > 0);
    }
    (reg.origin, seq)
}

/// A read-only window into a shared buffer of `T`s: `Arc` backing
/// allocation plus offset/length.  Clone is a refcount bump;
/// [`OperandView::slice`] narrows the window without touching the data.
/// Jobs, backends, and the wire codec all consume operands through this
/// one type; the default element type keeps the f32 reference path
/// spelled `OperandView` as before.
#[derive(Clone)]
pub struct OperandView<T: OperandScalar = f32> {
    buf: Arc<Vec<T>>,
    off: usize,
    len: usize,
}

impl<T: OperandScalar> OperandView<T> {
    /// A view over an entire shared buffer.
    pub fn full(buf: Arc<Vec<T>>) -> OperandView<T> {
        let len = buf.len();
        OperandView { buf, off: 0, len }
    }

    /// A view over `buf[off..off + len]`; panics if the window is out of
    /// bounds.
    pub fn new(buf: Arc<Vec<T>>, off: usize, len: usize) -> OperandView<T> {
        assert!(
            off.checked_add(len).is_some_and(|end| end <= buf.len()),
            "operand view {off}+{len} outside buffer of {}",
            buf.len()
        );
        OperandView { buf, off, len }
    }

    /// Narrow this view to `self[off..off + len]` (offsets relative to the
    /// view, not the backing buffer).  Shares the backing `Arc`.
    pub fn slice(&self, off: usize, len: usize) -> OperandView<T> {
        assert!(
            off.checked_add(len).is_some_and(|end| end <= self.len),
            "operand sub-view {off}+{len} outside view of {}",
            self.len
        );
        OperandView {
            buf: Arc::clone(&self.buf),
            off: self.off + off,
            len,
        }
    }

    /// The viewed elements.
    pub fn as_slice(&self) -> &[T] {
        &self.buf[self.off..self.off + self.len]
    }

    /// The shared backing allocation (for aliasing checks — `Arc::ptr_eq`
    /// against an arena chunk or a weight prepack).
    pub fn buffer(&self) -> &Arc<Vec<T>> {
        &self.buf
    }

    /// Offset of this view within its backing buffer.
    pub fn offset(&self) -> usize {
        self.off
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl<T: OperandScalar> Deref for OperandView<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: OperandScalar> From<Arc<Vec<T>>> for OperandView<T> {
    fn from(buf: Arc<Vec<T>>) -> OperandView<T> {
        OperandView::full(buf)
    }
}

impl<T: OperandScalar> From<Vec<T>> for OperandView<T> {
    fn from(v: Vec<T>) -> OperandView<T> {
        OperandView::full(Arc::new(v))
    }
}

impl<T: OperandScalar> std::fmt::Debug for OperandView<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // The buffer may be megabytes; print the window, not the data.
        f.debug_struct("OperandView")
            .field("dtype", &T::LABEL)
            .field("off", &self.off)
            .field("len", &self.len)
            .field("buf_len", &self.buf.len())
            .finish()
    }
}

/// A dtype-tagged shared operand plane — the erased form of an
/// [`OperandView`] backing buffer, for containers that must hold
/// heterogeneous dtypes side by side (the remote shard's operand cache
/// stores f32 fetch sets and i8 quantized planes under one `OperandKey`
/// namespace).
#[derive(Debug, Clone)]
pub enum Plane {
    F32(Arc<Vec<f32>>),
    I8(Arc<Vec<i8>>),
}

impl Plane {
    /// Element count of the backing allocation.
    pub fn len(&self) -> usize {
        match self {
            Plane::F32(b) => b.len(),
            Plane::I8(b) => b.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes of the backing allocation (cache byte accounting —
    /// an i8 plane costs 4× less than an f32 plane of equal length).
    pub fn bytes(&self) -> usize {
        match self {
            Plane::F32(b) => b.len() * f32::BYTES,
            Plane::I8(b) => b.len() * i8::BYTES,
        }
    }

    /// The plane's stable operand key (shared with every view over it).
    pub fn key(&self) -> OperandKey {
        match self {
            Plane::F32(b) => operand_key(b),
            Plane::I8(b) => operand_key(b),
        }
    }

    /// dtype label ("f32" / "i8") for ledgers and debug output.
    pub fn dtype(&self) -> &'static str {
        match self {
            Plane::F32(_) => f32::LABEL,
            Plane::I8(_) => i8::LABEL,
        }
    }

    /// The f32 backing allocation, or `None` for a non-f32 plane.
    pub fn as_f32(&self) -> Option<&Arc<Vec<f32>>> {
        match self {
            Plane::F32(b) => Some(b),
            Plane::I8(_) => None,
        }
    }

    /// The i8 backing allocation, or `None` for a non-i8 plane.
    pub fn as_i8(&self) -> Option<&Arc<Vec<i8>>> {
        match self {
            Plane::I8(b) => Some(b),
            Plane::F32(_) => None,
        }
    }
}

impl From<Arc<Vec<f32>>> for Plane {
    fn from(b: Arc<Vec<f32>>) -> Plane {
        Plane::F32(b)
    }
}

impl From<Arc<Vec<i8>>> for Plane {
    fn from(b: Arc<Vec<i8>>) -> Plane {
        Plane::I8(b)
    }
}

/// A per-frame bump arena: owns the frame's transient operand buffers so
/// jobs can alias them via views and the whole working set drops at once.
/// Allocation freezes each buffer into an `Arc` chunk; [`FrameArena::holds`]
/// answers whether a view aliases one of this arena's chunks (the
/// zero-copy proof the tests pin).  Quantized activation planes get their
/// own i8 side — same lifetime discipline, 4× smaller chunks.
#[derive(Default)]
pub struct FrameArena {
    chunks: Vec<Arc<Vec<f32>>>,
    chunks_i8: Vec<Arc<Vec<i8>>>,
}

impl FrameArena {
    pub fn new() -> FrameArena {
        FrameArena::default()
    }

    /// Allocate a zeroed `len`-element chunk, let `fill` write it in
    /// place, freeze it, and return a view over the whole chunk.
    pub fn alloc_with(&mut self, len: usize, fill: impl FnOnce(&mut [f32])) -> OperandView {
        let mut buf = vec![0.0f32; len];
        fill(&mut buf);
        self.adopt(buf)
    }

    /// Adopt an already-built buffer into the arena without copying it
    /// (how im2col results enter the frame's working set) and return a
    /// view over it.
    pub fn adopt(&mut self, buf: Vec<f32>) -> OperandView {
        let chunk = Arc::new(buf);
        self.chunks.push(Arc::clone(&chunk));
        OperandView::full(chunk)
    }

    /// Allocate a zeroed `len`-element i8 chunk, let `fill` write it in
    /// place, freeze it, and return a view over the whole chunk (how
    /// per-frame quantized activation planes are built).
    pub fn alloc_i8_with(&mut self, len: usize, fill: impl FnOnce(&mut [i8])) -> OperandView<i8> {
        let mut buf = vec![0i8; len];
        fill(&mut buf);
        self.adopt_i8(buf)
    }

    /// Adopt an already-built i8 buffer into the arena without copying it
    /// and return a view over it.
    pub fn adopt_i8(&mut self, buf: Vec<i8>) -> OperandView<i8> {
        let chunk = Arc::new(buf);
        self.chunks_i8.push(Arc::clone(&chunk));
        OperandView::full(chunk)
    }

    /// Does `view` alias one of this arena's f32 chunks?
    pub fn holds(&self, view: &OperandView) -> bool {
        self.chunks.iter().any(|c| Arc::ptr_eq(c, view.buffer()))
    }

    /// Does `view` alias one of this arena's i8 chunks?
    pub fn holds_i8(&self, view: &OperandView<i8>) -> bool {
        self.chunks_i8.iter().any(|c| Arc::ptr_eq(c, view.buffer()))
    }

    /// Number of f32 chunks allocated into this arena.
    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }

    /// Number of i8 chunks allocated into this arena.
    pub fn i8_chunk_count(&self) -> usize {
        self.chunks_i8.len()
    }

    /// Total f32 elements held by this arena.
    pub fn elems(&self) -> usize {
        self.chunks.iter().map(|c| c.len()).sum()
    }

    /// Total i8 elements held by this arena.
    pub fn i8_elems(&self) -> usize {
        self.chunks_i8.iter().map(|c| c.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn views_share_one_allocation() {
        let buf = Arc::new((0..100).map(|i| i as f32).collect::<Vec<f32>>());
        let v = OperandView::full(Arc::clone(&buf));
        assert_eq!(v.len(), 100);
        assert_eq!(v.offset(), 0);
        let s = v.slice(10, 20);
        assert_eq!(s.offset(), 10);
        assert_eq!(s.len(), 20);
        assert_eq!(s[0], 10.0);
        assert_eq!(&s[..3], &[10.0, 11.0, 12.0]);
        // Slices and clones all alias the one backing allocation.
        assert!(Arc::ptr_eq(s.buffer(), &buf));
        assert!(Arc::ptr_eq(v.clone().buffer(), &buf));
        // Nested slicing composes offsets.
        let ss = s.slice(5, 5);
        assert_eq!(ss.offset(), 15);
        assert_eq!(ss[0], 15.0);
    }

    #[test]
    fn i8_views_share_one_allocation() {
        let buf = Arc::new((0..32).map(|i| i as i8).collect::<Vec<i8>>());
        let v: OperandView<i8> = OperandView::full(Arc::clone(&buf));
        assert_eq!(v.len(), 32);
        let s = v.slice(8, 8);
        assert_eq!(s[0], 8);
        assert!(Arc::ptr_eq(s.buffer(), &buf));
        assert!(format!("{v:?}").contains("\"i8\""), "{v:?}");
    }

    #[test]
    #[should_panic(expected = "outside view")]
    fn slice_bounds_are_checked() {
        let v = OperandView::from(vec![0.0f32; 8]);
        let _ = v.slice(4, 5);
    }

    #[test]
    #[should_panic(expected = "outside buffer")]
    fn new_bounds_are_checked() {
        let buf = Arc::new(vec![0.0f32; 8]);
        let _ = OperandView::new(buf, 6, 3);
    }

    #[test]
    fn arena_tracks_and_identifies_its_chunks() {
        let mut arena = FrameArena::new();
        let a = arena.alloc_with(16, |dst| dst[3] = 7.0);
        assert_eq!(a[3], 7.0);
        assert_eq!(a.len(), 16);
        let b = arena.adopt(vec![1.0; 8]);
        assert_eq!(arena.chunk_count(), 2);
        assert_eq!(arena.elems(), 24);
        assert!(arena.holds(&a));
        assert!(arena.holds(&b));
        assert!(arena.holds(&a.slice(2, 4)), "sub-views alias the chunk too");
        let foreign = OperandView::from(vec![0.0f32; 4]);
        assert!(!arena.holds(&foreign));
    }

    #[test]
    fn arena_tracks_i8_chunks_separately() {
        let mut arena = FrameArena::new();
        let q = arena.alloc_i8_with(16, |dst| dst[3] = 7);
        assert_eq!(q[3], 7);
        let q2 = arena.adopt_i8(vec![1i8; 8]);
        assert_eq!(arena.i8_chunk_count(), 2);
        assert_eq!(arena.i8_elems(), 24);
        assert_eq!(arena.chunk_count(), 0, "i8 chunks do not count as f32");
        assert!(arena.holds_i8(&q) && arena.holds_i8(&q2));
        assert!(arena.holds_i8(&q.slice(2, 4)));
        let foreign = OperandView::<i8>::from(vec![0i8; 4]);
        assert!(!arena.holds_i8(&foreign));
    }

    #[test]
    fn operand_keys_are_stable_per_allocation_and_fresh_per_repack() {
        let a = Arc::new(vec![1.0f32; 64]);
        let k1 = operand_key(&a);
        let k2 = operand_key(&a);
        assert_eq!(k1, k2, "same allocation keys identically");
        assert_eq!(operand_key(&Arc::clone(&a)), k1, "clones share the key");

        let b = Arc::new(vec![1.0f32; 64]);
        assert_ne!(operand_key(&b), k1, "equal bytes, distinct identity");

        // A "pack-generation bump": drop the old buffer, build a new one.
        // Even if the allocator reuses the address, the Weak witness is
        // dead, so the new buffer must mint a new sequence.
        let old_key = operand_key(&a);
        drop(a);
        let repacked = Arc::new(vec![2.0f32; 64]);
        assert_ne!(operand_key(&repacked), old_key);

        // Origin is shared within the process, sequences are unique.
        assert_eq!(operand_key(&b).0, operand_key(&repacked).0);
        assert_ne!(operand_key(&b).1, operand_key(&repacked).1);
    }

    #[test]
    fn operand_keys_span_dtypes_in_one_namespace() {
        let f = Arc::new(vec![0.0f32; 16]);
        let q = Arc::new(vec![0i8; 16]);
        let kf = operand_key(&f);
        let kq = operand_key(&q);
        assert_ne!(kf, kq, "distinct allocations key distinctly");
        assert_eq!(kf.0, kq.0, "one origin nonce per process");
        assert_eq!(operand_key(&q), kq, "i8 keys are stable too");
    }

    #[test]
    fn planes_carry_dtype_and_byte_accounting() {
        let f = Arc::new(vec![0.0f32; 16]);
        let q = Arc::new(vec![0i8; 16]);
        let pf = Plane::from(Arc::clone(&f));
        let pq = Plane::from(Arc::clone(&q));
        assert_eq!(pf.len(), 16);
        assert_eq!(pq.len(), 16);
        assert_eq!(pf.bytes(), 64, "f32 plane is 4 bytes per element");
        assert_eq!(pq.bytes(), 16, "i8 plane is 1 byte per element");
        assert_eq!(pf.dtype(), "f32");
        assert_eq!(pq.dtype(), "i8");
        assert_eq!(pf.key(), operand_key(&f), "plane key == view key");
        assert_eq!(pq.key(), operand_key(&q));
        assert!(pf.as_f32().is_some() && pf.as_i8().is_none());
        assert!(pq.as_i8().is_some() && pq.as_f32().is_none());
        assert!(!pf.is_empty());
    }

    #[test]
    fn copy_ledger_moves_on_note_copy() {
        let b0 = copied_bytes();
        let e0 = copy_events();
        note_copy(128);
        assert!(copied_bytes() >= b0 + 128);
        assert!(copy_events() >= e0 + 1);
    }
}
