//! The zero-copy operand plane: shared-buffer operand views and the
//! per-frame bump arena the runtime packs operands into.
//!
//! Every pool [`Job`](crate::mm::Job) used to own `Vec<f32>` operands —
//! CONV tiles re-packed a (K,TS,TS) fetch set per job, fused FC batches
//! cloned their activation columns, and weights were re-packed on every
//! dispatch.  An [`OperandView`] replaces the owned buffers: an `Arc`
//! backing allocation plus an offset/length window into it.  Cloning a
//! view is a refcount bump; slicing is arithmetic; the bytes move exactly
//! once — when a layout transform packs them into a fresh buffer (counted
//! by [`copied_bytes`]/[`copy_events`]) or when the remote `wire` codec
//! serializes a view for shipping.
//!
//! A [`FrameArena`] owns the per-frame transient buffers (im2col columns,
//! packed B panels, fused FC column packs): the frame executor allocates
//! into it, jobs carry views that alias its chunks, and the whole frame's
//! working set is dropped at once when the arena goes out of scope.
//! Load-time weight prepacks live on the `Network` instead and are aliased
//! by every frame's jobs for the network's lifetime.

use crate::util::sync::{lock_clean, Mutex};
use std::collections::HashMap;
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, Weak};

/// Process-wide layout-transform copy ledger: bytes that were actually
/// copied into a fresh buffer (tile packing, FC column packing).  Cheap
/// view clones and arena adoptions do NOT count — that is the point.
static COPIED_BYTES: AtomicU64 = AtomicU64::new(0);
static COPY_EVENTS: AtomicU64 = AtomicU64::new(0);

/// Record one layout-transform copy of `bytes` bytes.  Called by the
/// pack/extract helpers in `mm::tile` and `mm::job`; everything else in
/// the operand plane moves views, not bytes.
pub(crate) fn note_copy(bytes: usize) {
    COPIED_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
    COPY_EVENTS.fetch_add(1, Ordering::Relaxed);
}

/// Total bytes copied by operand layout transforms since process start.
pub fn copied_bytes() -> u64 {
    COPIED_BYTES.load(Ordering::Relaxed)
}

/// Total layout-transform copy events since process start.
pub fn copy_events() -> u64 {
    COPY_EVENTS.load(Ordering::Relaxed)
}

/// Content-addressed identity of a shared operand buffer: a per-process
/// origin nonce plus a monotone sequence number minted the first time a
/// buffer is keyed.  Two views over the same `Arc` allocation share a key;
/// a repack into a fresh allocation (a weight pack-generation bump, a new
/// frame arena chunk) gets a fresh key — which is exactly what lets a
/// remote shard cache packed fetch sets by identity and lets the client
/// detect "this slot now holds different bytes" without hashing them.
pub type OperandKey = (u64, u64);

struct KeyRegistry {
    origin: u64,
    next_seq: AtomicU64,
    /// `Arc::as_ptr` address → (sequence, liveness witness).  The `Weak`
    /// guards against address reuse: an allocation dropped and replaced by
    /// a new one at the same address must NOT inherit the old key.
    by_ptr: Mutex<HashMap<usize, (u64, Weak<Vec<f32>>)>>,
}

fn key_registry() -> &'static KeyRegistry {
    static REGISTRY: OnceLock<KeyRegistry> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        // A per-process random nonce (the std hash seed) so keys minted by
        // two different client processes never collide in one shard cache.
        use std::hash::{BuildHasher, Hasher};
        let mut h = std::collections::hash_map::RandomState::new().build_hasher();
        h.write_u64(0x6f70_6572_616e_6421);
        KeyRegistry {
            origin: h.finish(),
            next_seq: AtomicU64::new(1),
            by_ptr: Mutex::new(HashMap::new()),
        }
    })
}

/// Stable cache key of a shared operand buffer.  Idempotent per live
/// allocation; process-wide, so every `RemoteShard` in this process keys
/// the same prepack identically and a shard dedupes across connections.
pub fn operand_key(buf: &Arc<Vec<f32>>) -> OperandKey {
    let reg = key_registry();
    let ptr = Arc::as_ptr(buf) as usize;
    let mut map = lock_clean(&reg.by_ptr);
    if let Some((seq, witness)) = map.get(&ptr) {
        if let Some(live) = witness.upgrade() {
            if Arc::ptr_eq(&live, buf) {
                return (reg.origin, *seq);
            }
        }
    }
    // First sighting (or a dead entry's address was reused): mint fresh.
    let seq = reg.next_seq.fetch_add(1, Ordering::Relaxed);
    map.insert(ptr, (seq, Arc::downgrade(buf)));
    // Bound the map: dead entries whose address never gets reused would
    // otherwise accumulate for the process lifetime.
    if map.len() > 4096 {
        map.retain(|_, (_, w)| w.strong_count() > 0);
    }
    (reg.origin, seq)
}

/// A read-only window into a shared f32 buffer: `Arc` backing allocation
/// plus offset/length.  Clone is a refcount bump; [`OperandView::slice`]
/// narrows the window without touching the data.  Jobs, backends, and the
/// wire codec all consume operands through this one type.
#[derive(Clone)]
pub struct OperandView {
    buf: Arc<Vec<f32>>,
    off: usize,
    len: usize,
}

impl OperandView {
    /// A view over an entire shared buffer.
    pub fn full(buf: Arc<Vec<f32>>) -> OperandView {
        let len = buf.len();
        OperandView { buf, off: 0, len }
    }

    /// A view over `buf[off..off + len]`; panics if the window is out of
    /// bounds.
    pub fn new(buf: Arc<Vec<f32>>, off: usize, len: usize) -> OperandView {
        assert!(
            off.checked_add(len).is_some_and(|end| end <= buf.len()),
            "operand view {off}+{len} outside buffer of {}",
            buf.len()
        );
        OperandView { buf, off, len }
    }

    /// Narrow this view to `self[off..off + len]` (offsets relative to the
    /// view, not the backing buffer).  Shares the backing `Arc`.
    pub fn slice(&self, off: usize, len: usize) -> OperandView {
        assert!(
            off.checked_add(len).is_some_and(|end| end <= self.len),
            "operand sub-view {off}+{len} outside view of {}",
            self.len
        );
        OperandView {
            buf: Arc::clone(&self.buf),
            off: self.off + off,
            len,
        }
    }

    /// The viewed elements.
    pub fn as_slice(&self) -> &[f32] {
        &self.buf[self.off..self.off + self.len]
    }

    /// The shared backing allocation (for aliasing checks — `Arc::ptr_eq`
    /// against an arena chunk or a weight prepack).
    pub fn buffer(&self) -> &Arc<Vec<f32>> {
        &self.buf
    }

    /// Offset of this view within its backing buffer.
    pub fn offset(&self) -> usize {
        self.off
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Deref for OperandView {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl From<Arc<Vec<f32>>> for OperandView {
    fn from(buf: Arc<Vec<f32>>) -> OperandView {
        OperandView::full(buf)
    }
}

impl From<Vec<f32>> for OperandView {
    fn from(v: Vec<f32>) -> OperandView {
        OperandView::full(Arc::new(v))
    }
}

impl std::fmt::Debug for OperandView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // The buffer may be megabytes; print the window, not the data.
        f.debug_struct("OperandView")
            .field("off", &self.off)
            .field("len", &self.len)
            .field("buf_len", &self.buf.len())
            .finish()
    }
}

/// A per-frame bump arena: owns the frame's transient operand buffers so
/// jobs can alias them via views and the whole working set drops at once.
/// Allocation freezes each buffer into an `Arc` chunk; [`FrameArena::holds`]
/// answers whether a view aliases one of this arena's chunks (the
/// zero-copy proof the tests pin).
#[derive(Default)]
pub struct FrameArena {
    chunks: Vec<Arc<Vec<f32>>>,
}

impl FrameArena {
    pub fn new() -> FrameArena {
        FrameArena::default()
    }

    /// Allocate a zeroed `len`-element chunk, let `fill` write it in
    /// place, freeze it, and return a view over the whole chunk.
    pub fn alloc_with(&mut self, len: usize, fill: impl FnOnce(&mut [f32])) -> OperandView {
        let mut buf = vec![0.0f32; len];
        fill(&mut buf);
        self.adopt(buf)
    }

    /// Adopt an already-built buffer into the arena without copying it
    /// (how im2col results enter the frame's working set) and return a
    /// view over it.
    pub fn adopt(&mut self, buf: Vec<f32>) -> OperandView {
        let chunk = Arc::new(buf);
        self.chunks.push(Arc::clone(&chunk));
        OperandView::full(chunk)
    }

    /// Does `view` alias one of this arena's chunks?
    pub fn holds(&self, view: &OperandView) -> bool {
        self.chunks.iter().any(|c| Arc::ptr_eq(c, view.buffer()))
    }

    /// Number of chunks allocated into this arena.
    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }

    /// Total f32 elements held by this arena.
    pub fn elems(&self) -> usize {
        self.chunks.iter().map(|c| c.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn views_share_one_allocation() {
        let buf = Arc::new((0..100).map(|i| i as f32).collect::<Vec<f32>>());
        let v = OperandView::full(Arc::clone(&buf));
        assert_eq!(v.len(), 100);
        assert_eq!(v.offset(), 0);
        let s = v.slice(10, 20);
        assert_eq!(s.offset(), 10);
        assert_eq!(s.len(), 20);
        assert_eq!(s[0], 10.0);
        assert_eq!(&s[..3], &[10.0, 11.0, 12.0]);
        // Slices and clones all alias the one backing allocation.
        assert!(Arc::ptr_eq(s.buffer(), &buf));
        assert!(Arc::ptr_eq(v.clone().buffer(), &buf));
        // Nested slicing composes offsets.
        let ss = s.slice(5, 5);
        assert_eq!(ss.offset(), 15);
        assert_eq!(ss[0], 15.0);
    }

    #[test]
    #[should_panic(expected = "outside view")]
    fn slice_bounds_are_checked() {
        let v = OperandView::from(vec![0.0f32; 8]);
        let _ = v.slice(4, 5);
    }

    #[test]
    #[should_panic(expected = "outside buffer")]
    fn new_bounds_are_checked() {
        let buf = Arc::new(vec![0.0f32; 8]);
        let _ = OperandView::new(buf, 6, 3);
    }

    #[test]
    fn arena_tracks_and_identifies_its_chunks() {
        let mut arena = FrameArena::new();
        let a = arena.alloc_with(16, |dst| dst[3] = 7.0);
        assert_eq!(a[3], 7.0);
        assert_eq!(a.len(), 16);
        let b = arena.adopt(vec![1.0; 8]);
        assert_eq!(arena.chunk_count(), 2);
        assert_eq!(arena.elems(), 24);
        assert!(arena.holds(&a));
        assert!(arena.holds(&b));
        assert!(arena.holds(&a.slice(2, 4)), "sub-views alias the chunk too");
        let foreign = OperandView::from(vec![0.0f32; 4]);
        assert!(!arena.holds(&foreign));
    }

    #[test]
    fn operand_keys_are_stable_per_allocation_and_fresh_per_repack() {
        let a = Arc::new(vec![1.0f32; 64]);
        let k1 = operand_key(&a);
        let k2 = operand_key(&a);
        assert_eq!(k1, k2, "same allocation keys identically");
        assert_eq!(operand_key(&Arc::clone(&a)), k1, "clones share the key");

        let b = Arc::new(vec![1.0f32; 64]);
        assert_ne!(operand_key(&b), k1, "equal bytes, distinct identity");

        // A "pack-generation bump": drop the old buffer, build a new one.
        // Even if the allocator reuses the address, the Weak witness is
        // dead, so the new buffer must mint a new sequence.
        let old_key = operand_key(&a);
        drop(a);
        let repacked = Arc::new(vec![2.0f32; 64]);
        assert_ne!(operand_key(&repacked), old_key);

        // Origin is shared within the process, sequences are unique.
        assert_eq!(operand_key(&b).0, operand_key(&repacked).0);
        assert_ne!(operand_key(&b).1, operand_key(&repacked).1);
    }

    #[test]
    fn copy_ledger_moves_on_note_copy() {
        let b0 = copied_bytes();
        let e0 = copy_events();
        note_copy(128);
        assert!(copied_bytes() >= b0 + 128);
        assert!(copy_events() >= e0 + 1);
    }
}
