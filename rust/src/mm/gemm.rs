//! Native GEMM kernels.
//!
//! `gemm_naive` is the obviously-correct oracle; `gemm_blocked` is the
//! cache-blocked, unroll-friendly kernel that backs the NEON software
//! accelerator (the ARM assembly MM of paper §3.1.1 re-targeted to the
//! host's SIMD units via autovectorization).

use crate::tensor::Tensor;

/// Textbook triple loop — the oracle.
pub fn gemm_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let (n2, p) = (b.shape()[0], b.shape()[1]);
    assert_eq!(n, n2, "inner dims must match");
    let mut c = Tensor::zeros(&[m, p]);
    for i in 0..m {
        for j in 0..p {
            let mut acc = 0.0f32;
            for k in 0..n {
                acc += a.at2(i, k) * b.at2(k, j);
            }
            c.set2(i, j, acc);
        }
    }
    c
}

/// i-k-j loop order with row-axpy inner loop: the inner loop is a
/// contiguous fused multiply-add over C's row, which LLVM autovectorizes.
pub fn gemm_blocked(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let (n2, p) = (b.shape()[0], b.shape()[1]);
    assert_eq!(n, n2, "inner dims must match");
    let mut c = vec![0.0f32; m * p];
    gemm_blocked_into(a.data(), b.data(), &mut c, m, n, p);
    Tensor::from_vec(&[m, p], c)
}

/// Raw-slice core (shared with the job executor): **accumulates**
/// C[MxP] += A[MxN]·B[NxP].
///
/// `c` is an accumulator, not an output buffer: callers wanting plain
/// C = A·B must pass a zero-initialized `c` (as [`gemm_blocked`] does);
/// anything already in `c` is added to.  The debug assertions pin the
/// slice-geometry contract — a wrong-length `c` is the classic misuse
/// (non-finite values are deliberately *not* asserted: inf/NaN must
/// propagate through running accumulators, e.g. the per-k-tile calls in
/// `job_mm_native`).
pub fn gemm_blocked_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, p: usize) {
    debug_assert_eq!(a.len(), m * n, "A operand size");
    debug_assert_eq!(b.len(), n * p, "B operand size");
    debug_assert_eq!(c.len(), m * p, "C accumulator size");
    // Block the k dimension to keep B panels hot in L1/L2.
    const KB: usize = 256;
    for k0 in (0..n).step_by(KB) {
        let k1 = (k0 + KB).min(n);
        for i in 0..m {
            let a_row = &a[i * n..(i + 1) * n];
            let c_row = &mut c[i * p..(i + 1) * p];
            for k in k0..k1 {
                let aik = a_row[k];
                if aik == 0.0 {
                    continue;
                }
                let b_row = &b[k * p..(k + 1) * p];
                // contiguous axpy over the C row — autovectorizes
                for (cv, bv) in c_row.iter_mut().zip(b_row) {
                    *cv += aik * *bv;
                }
            }
        }
    }
}

/// Int8 twin of [`gemm_blocked_into`]: **accumulates**
/// C[MxP] += A[MxN]·B[NxP] with i8 operands widened into an i32
/// accumulator — the fixed-point datapath the DPU lineage gets its
/// embedded throughput from.  Same k-blocked i-k-j tiling discipline and
/// zero-skip as the f32 kernel; the accumulator is exact (no rounding
/// anywhere), so requantization is entirely the caller's business at the
/// layer boundary.
///
/// Overflow headroom: |a·b| ≤ 127² = 16129 per term, so an i32
/// accumulator is exact for any inner dimension n ≤ 2³¹/16129 ≈ 133k —
/// far beyond every zoo layer.  Debug builds assert the geometry like
/// the f32 kernel does.
pub fn gemm_q8_blocked_into(a: &[i8], b: &[i8], c: &mut [i32], m: usize, n: usize, p: usize) {
    debug_assert_eq!(a.len(), m * n, "A operand size");
    debug_assert_eq!(b.len(), n * p, "B operand size");
    debug_assert_eq!(c.len(), m * p, "C accumulator size");
    // Same KB as the f32 kernel: keeps B panels hot in L1/L2.
    const KB: usize = 256;
    for k0 in (0..n).step_by(KB) {
        let k1 = (k0 + KB).min(n);
        for i in 0..m {
            let a_row = &a[i * n..(i + 1) * n];
            let c_row = &mut c[i * p..(i + 1) * p];
            for k in k0..k1 {
                let aik = a_row[k] as i32;
                if aik == 0 {
                    continue;
                }
                let b_row = &b[k * p..(k + 1) * p];
                // contiguous integer axpy over the C row — autovectorizes
                // to widening multiply-accumulate lanes
                for (cv, bv) in c_row.iter_mut().zip(b_row) {
                    *cv += aik * *bv as i32;
                }
            }
        }
    }
}

/// FLOP count of an (m,n,p) GEMM (the paper's GOP accounting: 2·m·n·p).
pub fn gemm_flops(m: usize, n: usize, p: usize) -> u64 {
    2 * m as u64 * n as u64 * p as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift64Star;

    fn rand(shape: &[usize], seed: u64) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, XorShift64Star::new(seed).fill_f32(n, 2.0))
    }

    #[test]
    fn blocked_matches_naive() {
        for (m, n, p) in [(1, 1, 1), (4, 5, 6), (32, 32, 32), (50, 300, 45), (7, 513, 3)] {
            let a = rand(&[m, n], (m * 31 + n) as u64);
            let b = rand(&[n, p], (n * 17 + p) as u64);
            let want = gemm_naive(&a, &b);
            let got = gemm_blocked(&a, &b);
            assert!(
                want.allclose(&got, 1e-4, 1e-4),
                "mismatch at ({m},{n},{p}): {}",
                want.max_abs_diff(&got)
            );
        }
    }

    #[test]
    fn identity() {
        let n = 16;
        let mut eye = Tensor::zeros(&[n, n]);
        for i in 0..n {
            eye.set2(i, i, 1.0);
        }
        let x = rand(&[n, n], 3);
        let y = gemm_blocked(&eye, &x);
        assert!(y.allclose(&x, 1e-6, 1e-6));
    }

    #[test]
    fn accumulates_into_existing_c() {
        let a = rand(&[3, 4], 1);
        let b = rand(&[4, 5], 2);
        let mut c = vec![1.0f32; 15];
        gemm_blocked_into(a.data(), b.data(), &mut c, 3, 4, 5);
        let base = gemm_blocked(&a, &b);
        for (got, want) in c.iter().zip(base.data()) {
            assert!((got - (want + 1.0)).abs() < 1e-5);
        }
    }

    #[test]
    fn flops_formula() {
        assert_eq!(gemm_flops(2, 3, 4), 48);
    }

    /// Property: the blocked kernel matches the naive oracle on ragged
    /// shapes whose inner dimension is *not* a multiple of the k-blocking
    /// factor (KB = 256), pinning the k0..k1 tail-block handling.
    #[test]
    fn prop_blocked_matches_naive_ragged_kb() {
        crate::util::proptest::check("gemm-ragged-kb", 20, |g| {
            let m = g.usize_in(1, 8);
            let p = g.usize_in(1, 8);
            // Straddle one or two KB blocks, never on a 256 boundary.
            let n = g.usize_in(0, 1) * 256 + g.usize_in(1, 255);
            assert_ne!(n % 256, 0);
            let a = Tensor::from_vec(&[m, n], g.vec_f32(m * n));
            let b = Tensor::from_vec(&[n, p], g.vec_f32(n * p));
            let want = gemm_naive(&a, &b);
            let got = gemm_blocked(&a, &b);
            assert!(
                want.allclose(&got, 1e-3, 1e-3),
                "({m},{n},{p}): {}",
                want.max_abs_diff(&got)
            );
        });
    }

    fn rand_q8(n: usize, seed: u64) -> Vec<i8> {
        (0..n)
            .map(|i| (((i as u64 * 31 + seed * 7 + 3) % 255) as i64 - 127) as i8)
            .collect()
    }

    /// The i8 kernel must equal a plain i64 integer oracle exactly —
    /// there is no floating point anywhere in the accumulation.
    #[test]
    fn q8_blocked_matches_integer_oracle() {
        for (m, n, p) in [(1, 1, 1), (4, 5, 6), (32, 32, 32), (7, 513, 3), (3, 300, 5)] {
            let a = rand_q8(m * n, (m + n) as u64);
            let b = rand_q8(n * p, (n + p) as u64);
            let mut c = vec![0i32; m * p];
            gemm_q8_blocked_into(&a, &b, &mut c, m, n, p);
            for i in 0..m {
                for j in 0..p {
                    let want: i64 =
                        (0..n).map(|k| a[i * n + k] as i64 * b[k * p + j] as i64).sum();
                    assert_eq!(c[i * p + j] as i64, want, "({m},{n},{p}) at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn q8_accumulates_into_existing_c() {
        let a = rand_q8(3 * 4, 1);
        let b = rand_q8(4 * 5, 2);
        let mut base = vec![0i32; 15];
        gemm_q8_blocked_into(&a, &b, &mut base, 3, 4, 5);
        let mut c = vec![10i32; 15];
        gemm_q8_blocked_into(&a, &b, &mut c, 3, 4, 5);
        for (got, want) in c.iter().zip(&base) {
            assert_eq!(*got, want + 10);
        }
    }

    /// Worst-case magnitude codes over a deep inner dimension stay exact
    /// in i32 (the headroom argument in the kernel doc).
    #[test]
    fn q8_extreme_codes_do_not_overflow_i32() {
        let n = 4096;
        let a = vec![127i8; n];
        let b = vec![-127i8; n];
        let mut c = vec![0i32; 1];
        gemm_q8_blocked_into(&a, &b, &mut c, 1, n, 1);
        assert_eq!(c[0] as i64, -(127i64 * 127 * n as i64));
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        gemm_naive(&a, &b);
    }
}
