//! Tiled matrix multiplication: the computational currency of Synergy.
//!
//! CONV layers are lowered to GEMM (im2col), the GEMM iteration space is
//! tiled (paper Listing 1), and each output tile becomes a *job* (paper
//! Listing 2 / Fig 3) dispatched to heterogeneous accelerators.

pub mod gemm;
pub mod job;
pub mod operand;
pub mod tile;

pub use job::{ClassMask, Classed, Job, JobClass, JobDesc, JobKind, JobResult};
pub use operand::{operand_key, FrameArena, OperandKey, OperandScalar, OperandView, Plane};
pub use tile::TileGrid;
