//! Tiling geometry + tile extraction with the paper's zero-padding border
//! semantics (§3.2.1): fetches beyond the matrix border read zeros, stores
//! beyond it are dropped.

use crate::tensor::Tensor;

/// The tiled iteration space of one GEMM: C[M,P] = A[M,N]·B[N,P] with
/// (TS,TS) tiles.  A *job* computes one (t1,t2) output tile by iterating
/// all K = ceil(N/TS) inner tiles (paper Fig 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileGrid {
    pub m: usize,
    pub n: usize,
    pub p: usize,
    pub ts: usize,
}

impl TileGrid {
    pub fn new(m: usize, n: usize, p: usize, ts: usize) -> Self {
        assert!(ts > 0 && m > 0 && n > 0 && p > 0);
        Self { m, n, p, ts }
    }

    /// Output tile rows: ceil(M/TS).
    pub fn rows(&self) -> usize {
        self.m.div_ceil(self.ts)
    }

    /// Output tile cols: ceil(P/TS).
    pub fn cols(&self) -> usize {
        self.p.div_ceil(self.ts)
    }

    /// Inner (shared-dim) tiles per job: ceil(N/TS).
    pub fn k_tiles(&self) -> usize {
        self.n.div_ceil(self.ts)
    }

    /// Total jobs for this GEMM.
    pub fn num_jobs(&self) -> usize {
        self.rows() * self.cols()
    }

    /// f32 elements in one packed operand panel: K (TS,TS) tiles.
    pub fn panel_elems(&self) -> usize {
        self.k_tiles() * self.ts * self.ts
    }

    /// Extract A's row-panel for output tile row `t1` as K packed (TS,TS)
    /// tiles (zero-padded at borders) — the PE's fetch of step ② in
    /// paper Listing 3.
    pub fn extract_a_tiles(&self, a: &[f32], t1: usize) -> Vec<f32> {
        debug_assert_eq!(a.len(), self.m * self.n);
        let ts = self.ts;
        let k_tiles = self.k_tiles();
        let mut out = vec![0.0f32; k_tiles * ts * ts];
        let row0 = t1 * ts;
        for kt in 0..k_tiles {
            let col0 = kt * ts;
            let dst = &mut out[kt * ts * ts..(kt + 1) * ts * ts];
            pack_tile(a, self.m, self.n, row0, col0, ts, dst);
        }
        super::operand::note_copy(out.len() * 4);
        out
    }

    /// Extract B's column-panel for output tile col `t2` as K packed tiles.
    pub fn extract_b_tiles(&self, b: &[f32], t2: usize) -> Vec<f32> {
        debug_assert_eq!(b.len(), self.n * self.p);
        let ts = self.ts;
        let k_tiles = self.k_tiles();
        let mut out = vec![0.0f32; k_tiles * ts * ts];
        let col0 = t2 * ts;
        for kt in 0..k_tiles {
            let row0 = kt * ts;
            let dst = &mut out[kt * ts * ts..(kt + 1) * ts * ts];
            pack_tile(b, self.n, self.p, row0, col0, ts, dst);
        }
        super::operand::note_copy(out.len() * 4);
        out
    }

    /// Pack the WHOLE dense A (M×N) into the blocked layout: rows() row
    /// panels of K (TS,TS) tiles each, panel `t1` at offset
    /// `t1 * panel_elems()`.  This is the once-per-GEMM (or, for weights,
    /// once-per-network-load) transform the per-job
    /// [`TileGrid::extract_a_tiles`] fetch used to repeat per tile row.
    pub fn pack_a_tiles(&self, a: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), self.m * self.n, "A operand size mismatch");
        let ts = self.ts;
        let panel = self.panel_elems();
        let mut out = vec![0.0f32; self.rows() * panel];
        for t1 in 0..self.rows() {
            let row0 = t1 * ts;
            for kt in 0..self.k_tiles() {
                let off = t1 * panel + kt * ts * ts;
                pack_tile(a, self.m, self.n, row0, kt * ts, ts, &mut out[off..off + ts * ts]);
            }
        }
        super::operand::note_copy(out.len() * 4);
        out
    }

    /// Pack the WHOLE dense B (N×P) into cols() column panels of K
    /// (TS,TS) tiles each, panel `t2` at offset `t2 * panel_elems()`.
    pub fn pack_b_tiles(&self, b: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols() * self.panel_elems()];
        self.pack_b_tiles_into(b, &mut out);
        out
    }

    /// [`TileGrid::pack_b_tiles`] into a caller-provided (arena) buffer of
    /// `cols() * panel_elems()` zeroed f32s.
    pub fn pack_b_tiles_into(&self, b: &[f32], out: &mut [f32]) {
        assert_eq!(b.len(), self.n * self.p, "B operand size mismatch");
        let ts = self.ts;
        let panel = self.panel_elems();
        assert_eq!(out.len(), self.cols() * panel, "packed B buffer size mismatch");
        for t2 in 0..self.cols() {
            let col0 = t2 * ts;
            for kt in 0..self.k_tiles() {
                let off = t2 * panel + kt * ts * ts;
                pack_tile(b, self.n, self.p, kt * ts, col0, ts, &mut out[off..off + ts * ts]);
            }
        }
        super::operand::note_copy(out.len() * 4);
    }

    /// Scatter a computed (TS,TS) output tile back into C, dropping
    /// out-of-border writes (paper: "ignores write requests if a memory
    /// address exceeds the given matrix borders").
    pub fn scatter_c(&self, c: &mut [f32], t1: usize, t2: usize, tile: &[f32]) {
        debug_assert_eq!(c.len(), self.m * self.p);
        debug_assert_eq!(tile.len(), self.ts * self.ts);
        let ts = self.ts;
        let row0 = t1 * ts;
        let col0 = t2 * ts;
        let rows = ts.min(self.m.saturating_sub(row0));
        let cols = ts.min(self.p.saturating_sub(col0));
        for r in 0..rows {
            let src = &tile[r * ts..r * ts + cols];
            let dst = &mut c[(row0 + r) * self.p + col0..(row0 + r) * self.p + col0 + cols];
            dst.copy_from_slice(src);
        }
    }

    /// All (t1, t2) output tile coordinates, row-major.
    pub fn tiles(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        let cols = self.cols();
        (0..self.num_jobs()).map(move |i| (i / cols, i % cols))
    }
}

/// Copy a (ts,ts) window of `src` (rows×cols row-major) starting at
/// (row0,col0) into `dst`, zero-filling out-of-border lanes.
fn pack_tile(
    src: &[f32],
    rows: usize,
    cols: usize,
    row0: usize,
    col0: usize,
    ts: usize,
    dst: &mut [f32],
) {
    let r_max = ts.min(rows.saturating_sub(row0));
    let c_max = ts.min(cols.saturating_sub(col0));
    for r in 0..r_max {
        let s = &src[(row0 + r) * cols + col0..(row0 + r) * cols + col0 + c_max];
        dst[r * ts..r * ts + c_max].copy_from_slice(s);
        // rest of dst row stays zero
    }
}

/// Full tiled GEMM through the tile path (reference for job-level testing).
pub fn tiled_gemm(a: &Tensor, b: &Tensor, ts: usize) -> Tensor {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let p = b.shape()[1];
    let grid = TileGrid::new(m, n, p, ts);
    let mut c = vec![0.0f32; m * p];
    for (t1, t2) in grid.tiles() {
        let at = grid.extract_a_tiles(a.data(), t1);
        let bt = grid.extract_b_tiles(b.data(), t2);
        let tile = job_mm_native(&at, &bt, grid.k_tiles(), ts);
        grid.scatter_c(&mut c, t1, t2, &tile);
    }
    Tensor::from_vec(&[m, p], c)
}

/// Native job kernel: C_tile = Σ_k A_k·B_k over packed (K,TS,TS) buffers —
/// the same computation the AOT Pallas artifact performs on the PE path.
pub fn job_mm_native(a_tiles: &[f32], b_tiles: &[f32], k_tiles: usize, ts: usize) -> Vec<f32> {
    debug_assert_eq!(a_tiles.len(), k_tiles * ts * ts);
    debug_assert_eq!(b_tiles.len(), k_tiles * ts * ts);
    let mut c = vec![0.0f32; ts * ts];
    for kt in 0..k_tiles {
        let a = &a_tiles[kt * ts * ts..(kt + 1) * ts * ts];
        let b = &b_tiles[kt * ts * ts..(kt + 1) * ts * ts];
        if ts == 32 {
            // Fixed-bound micro-kernel: compile-time 32s let LLVM fully
            // unroll + vectorize the axpy rows (§Perf iteration 2).
            mm32_into(a, b, &mut c);
        } else {
            super::gemm::gemm_blocked_into(a, b, &mut c, ts, ts, ts);
        }
    }
    c
}

/// Int8 twin of [`job_mm_native`]: C_tile = scale · Σ_k Aq_k·Bq_k over
/// packed i8 (K,TS,TS) panels.  The sum accumulates exactly in i32 across
/// ALL K inner tiles; the single dequantize multiply happens once at the
/// tile boundary — the requantization discipline the quantized layer
/// executor relies on for its drift bound.
pub fn job_mm_q8_native(
    a_tiles: &[i8],
    b_tiles: &[i8],
    k_tiles: usize,
    ts: usize,
    scale: f32,
) -> Vec<f32> {
    debug_assert_eq!(a_tiles.len(), k_tiles * ts * ts);
    debug_assert_eq!(b_tiles.len(), k_tiles * ts * ts);
    let mut acc = vec![0i32; ts * ts];
    for kt in 0..k_tiles {
        let a = &a_tiles[kt * ts * ts..(kt + 1) * ts * ts];
        let b = &b_tiles[kt * ts * ts..(kt + 1) * ts * ts];
        if ts == 32 {
            // Fixed-bound micro-kernel, same shape as the f32 path.
            mm32_q8_into(a, b, &mut acc);
        } else {
            super::gemm::gemm_q8_blocked_into(a, b, &mut acc, ts, ts, ts);
        }
    }
    acc.iter().map(|&v| v as f32 * scale).collect()
}

/// c[32,32] += a[32,32] · b[32,32] with compile-time bounds.
#[inline]
fn mm32_into(a: &[f32], b: &[f32], c: &mut [f32]) {
    let a: &[f32; 1024] = a.try_into().expect("32x32 tile");
    let b: &[f32; 1024] = b.try_into().expect("32x32 tile");
    let c: &mut [f32; 1024] = c.try_into().expect("32x32 tile");
    for i in 0..32 {
        for k in 0..32 {
            let aik = a[i * 32 + k];
            for j in 0..32 {
                c[i * 32 + j] += aik * b[k * 32 + j];
            }
        }
    }
}

/// c[32,32] += a[32,32] · b[32,32] over i8 codes into the i32
/// accumulator, with compile-time bounds (the widening-MAC twin of
/// [`mm32_into`]).
#[inline]
fn mm32_q8_into(a: &[i8], b: &[i8], c: &mut [i32]) {
    let a: &[i8; 1024] = a.try_into().expect("32x32 tile");
    let b: &[i8; 1024] = b.try_into().expect("32x32 tile");
    let c: &mut [i32; 1024] = c.try_into().expect("32x32 tile");
    for i in 0..32 {
        for k in 0..32 {
            let aik = a[i * 32 + k] as i32;
            for j in 0..32 {
                c[i * 32 + j] += aik * b[k * 32 + j] as i32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::gemm::gemm_naive;
    use crate::util::rng::XorShift64Star;

    fn rand(shape: &[usize], seed: u64) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, XorShift64Star::new(seed).fill_f32(n, 2.0))
    }

    #[test]
    fn grid_geometry() {
        let g = TileGrid::new(32, 75, 1024, 32);
        assert_eq!(g.rows(), 1);
        assert_eq!(g.cols(), 32);
        assert_eq!(g.k_tiles(), 3);
        assert_eq!(g.num_jobs(), 32);
        assert_eq!(g.tiles().count(), 32);
    }

    #[test]
    fn tiled_equals_naive_aligned() {
        let a = rand(&[64, 32], 1);
        let b = rand(&[32, 96], 2);
        let want = gemm_naive(&a, &b);
        let got = tiled_gemm(&a, &b, 32);
        assert!(want.allclose(&got, 1e-4, 1e-4));
    }

    #[test]
    fn tiled_equals_naive_ragged() {
        // Ragged in every dimension — exercises all border paths.
        for (m, n, p) in [(33, 65, 31), (1, 1, 1), (50, 70, 45), (31, 33, 64)] {
            let a = rand(&[m, n], (m + n) as u64);
            let b = rand(&[n, p], (n + p) as u64);
            let want = gemm_naive(&a, &b);
            let got = tiled_gemm(&a, &b, 32);
            assert!(
                want.allclose(&got, 1e-4, 1e-4),
                "({m},{n},{p}): {}",
                want.max_abs_diff(&got)
            );
        }
    }

    #[test]
    fn extract_zero_pads_border() {
        let g = TileGrid::new(3, 3, 3, 4); // single 4x4 tile over 3x3 data
        let a: Vec<f32> = (1..=9).map(|i| i as f32).collect();
        let tiles = g.extract_a_tiles(&a, 0);
        assert_eq!(tiles.len(), 16);
        assert_eq!(tiles[0], 1.0);
        assert_eq!(tiles[3], 0.0); // padded col
        assert_eq!(tiles[12], 0.0); // padded row
        assert_eq!(tiles[4 + 2], 6.0); // (1,2) = 6
    }

    #[test]
    fn scatter_drops_out_of_border() {
        let g = TileGrid::new(3, 4, 3, 4);
        let mut c = vec![0.0f32; 9];
        let tile: Vec<f32> = (0..16).map(|i| i as f32).collect();
        g.scatter_c(&mut c, 0, 0, &tile);
        // only 3x3 region written: rows of the tile are [0,1,2],[4,5,6],[8,9,10]
        assert_eq!(c, vec![0.0, 1.0, 2.0, 4.0, 5.0, 6.0, 8.0, 9.0, 10.0]);
    }

    #[test]
    fn whole_matrix_packs_match_per_panel_extracts() {
        let g = TileGrid::new(70, 40, 90, 32);
        let a = rand(&[70, 40], 21);
        let b = rand(&[40, 90], 22);
        let panel = g.panel_elems();
        let ap = g.pack_a_tiles(a.data());
        assert_eq!(ap.len(), g.rows() * panel);
        for t1 in 0..g.rows() {
            assert_eq!(
                &ap[t1 * panel..(t1 + 1) * panel],
                &g.extract_a_tiles(a.data(), t1)[..],
                "A panel {t1}"
            );
        }
        let bp = g.pack_b_tiles(b.data());
        assert_eq!(bp.len(), g.cols() * panel);
        for t2 in 0..g.cols() {
            assert_eq!(
                &bp[t2 * panel..(t2 + 1) * panel],
                &g.extract_b_tiles(b.data(), t2)[..],
                "B panel {t2}"
            );
        }
        // The into-variant writes the identical layout.
        let mut bp2 = vec![0.0f32; g.cols() * panel];
        g.pack_b_tiles_into(b.data(), &mut bp2);
        assert_eq!(bp, bp2);
    }

    /// The q8 tile kernel must equal an i64 integer oracle exactly for
    /// both the ts==32 micro-kernel and the generic blocked path.
    #[test]
    fn job_mm_q8_native_matches_integer_oracle() {
        for ts in [32usize, 16] {
            let k_tiles = 3;
            let n = k_tiles * ts * ts;
            let a: Vec<i8> =
                (0..n).map(|i| (((i * 29 + 5) % 255) as i64 - 127) as i8).collect();
            let b: Vec<i8> =
                (0..n).map(|i| (((i * 17 + 9) % 255) as i64 - 127) as i8).collect();
            let scale = 0.0625f32;
            let got = job_mm_q8_native(&a, &b, k_tiles, ts, scale);
            for i in 0..ts {
                for j in 0..ts {
                    let mut acc = 0i64;
                    for kt in 0..k_tiles {
                        let at = &a[kt * ts * ts..(kt + 1) * ts * ts];
                        let bt = &b[kt * ts * ts..(kt + 1) * ts * ts];
                        for k in 0..ts {
                            acc += at[i * ts + k] as i64 * bt[k * ts + j] as i64;
                        }
                    }
                    assert_eq!(got[i * ts + j], acc as f32 * scale, "ts={ts} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn job_mm_native_matches_flat_gemm() {
        let g = TileGrid::new(32, 64, 32, 32);
        let a = rand(&[32, 64], 9);
        let b = rand(&[64, 32], 10);
        let at = g.extract_a_tiles(a.data(), 0);
        let bt = g.extract_b_tiles(b.data(), 0);
        let tile = job_mm_native(&at, &bt, 2, 32);
        let want = gemm_naive(&a, &b);
        let got = Tensor::from_vec(&[32, 32], tile);
        assert!(want.allclose(&got, 1e-4, 1e-4));
    }
}
