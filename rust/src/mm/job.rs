//! The *job* — Synergy's workload granularity (paper Listing 2 / Fig 3),
//! generalized from CONV-tile GEMMs to every class of matrix work the
//! heterogeneous pool executes.
//!
//! The original paper job computes one (TS,TS) output tile C(t1,t2) of a
//! CONV layer's GEMM.  The unified runtime adds two more classes so the
//! whole forward pass — not just CONV GEMMs — flows through the shared
//! accelerator pool (§3.1 "unified abstraction"):
//!
//! * [`JobClass::ConvTile`] — one output tile of a tiled CONV GEMM;
//! * [`JobClass::FcGemm`] — a whole fully-connected layer GEMM (previously
//!   executed inline on the pipeline thread, the throughput killer the
//!   mobile-SoC studies identify);
//! * [`JobClass::Im2col`] — the im2col lowering of one CONV input;
//! * [`JobClass::FcGemmBatch`] — a micro-batch's worth of FC columns fused
//!   into one (OUT,IN)×(IN,B) GEMM, so the serving path pays one dispatch
//!   (and one big-NEON fan-out) per FC layer per *batch* instead of per
//!   request;
//! * [`JobClass::ConvTileQ8`] / [`JobClass::FcGemmQ8`] /
//!   [`JobClass::FcGemmBatchQ8`] — the int8 quantized twins of the three
//!   GEMM classes: i8 operand planes, i32 accumulation, one symmetric
//!   scale applied at the layer boundary.  A class per dtype is what lets
//!   the registry advertise quantized capability per backend — a member
//!   without the Q8 bits simply never sees quantized jobs, and the
//!   planner falls back to the dequantized f32 classes.
//!
//! Jobs carry what the paper's `job_t` carries: operand "base addresses"
//! (shared buffers), the matrix geometry, the tile index, and the owning
//! layer id — plus the frame id, since the pipelined design keeps multiple
//! frames in flight (§3.1.1 "inter-frame parallelism").
//!
//! Operands are [`OperandView`]s — offset/length windows into shared
//! buffers (the zero-copy operand plane, see `mm::operand`).  A CONV-tile
//! job carries views into the *pre-packed* (rows·K,TS,TS) /
//! (cols·K,TS,TS) operand panels ([`TileGrid::pack_a_tiles`] /
//! [`TileGrid::pack_b_tiles`]), so dispatching, stealing, and executing a
//! job never re-packs or copies operand bytes.

use super::operand::OperandView;
use super::tile::{job_mm_native, TileGrid};

/// Dense job-class tag — indexes the per-class counters kept by delegates,
/// the thief, and [`crate::rt::PoolReport`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobClass {
    /// One (TS,TS) output tile of a tiled CONV GEMM.
    ConvTile = 0,
    /// A whole FC-layer GEMM (W·x) executed as a single job.
    FcGemm = 1,
    /// im2col lowering of one CONV-layer input frame.
    Im2col = 2,
    /// A fused FC GEMM over a micro-batch: Y(OUT,B) = W(OUT,IN)·X(IN,B),
    /// one activation column per request.
    FcGemmBatch = 3,
    /// Int8 twin of [`JobClass::ConvTile`]: one (TS,TS) output tile over
    /// pre-quantized i8 operand panels, accumulated in i32.
    ConvTileQ8 = 4,
    /// Int8 twin of [`JobClass::FcGemm`].
    FcGemmQ8 = 5,
    /// Int8 twin of [`JobClass::FcGemmBatch`].
    FcGemmBatchQ8 = 6,
}

impl JobClass {
    /// Number of job classes (array sizing for per-class accounting).
    pub const COUNT: usize = 7;
    /// Every class, in dense-index order.
    pub const ALL: [JobClass; JobClass::COUNT] = [
        JobClass::ConvTile,
        JobClass::FcGemm,
        JobClass::Im2col,
        JobClass::FcGemmBatch,
        JobClass::ConvTileQ8,
        JobClass::FcGemmQ8,
        JobClass::FcGemmBatchQ8,
    ];

    /// Dense index into per-class counter arrays.
    pub const fn index(self) -> usize {
        self as usize
    }

    /// Is this one of the int8 quantized classes?  (The capability a
    /// backend claims — or doesn't — via [`ClassMask::Q8`].)
    pub const fn is_q8(self) -> bool {
        matches!(
            self,
            JobClass::ConvTileQ8 | JobClass::FcGemmQ8 | JobClass::FcGemmBatchQ8
        )
    }

    /// Default steal-policy cost weight of one queued job of this class.
    /// `sched::worksteal::DEFAULT_CLASS_COST` is derived from this table,
    /// so a new class cannot desync the cost array silently.  Q8 classes
    /// cost half their f32 twin: same k-step count, quarter operand bytes
    /// and a narrower MAC.
    pub const fn default_steal_cost(self) -> f64 {
        match self {
            JobClass::ConvTile => 1.0,
            JobClass::FcGemm => 4.0,
            JobClass::Im2col => 0.5,
            JobClass::FcGemmBatch => 16.0,
            JobClass::ConvTileQ8 => 0.5,
            JobClass::FcGemmQ8 => 2.0,
            JobClass::FcGemmBatchQ8 => 8.0,
        }
    }

    /// Human-readable label (reports and stats tables).
    pub fn label(self) -> &'static str {
        match self {
            JobClass::ConvTile => "conv-tile",
            JobClass::FcGemm => "fc-gemm",
            JobClass::Im2col => "im2col",
            JobClass::FcGemmBatch => "fc-gemm-batch",
            JobClass::ConvTileQ8 => "conv-tile-q8",
            JobClass::FcGemmQ8 => "fc-gemm-q8",
            JobClass::FcGemmBatchQ8 => "fc-gemm-batch-q8",
        }
    }
}

// `JobClass::ALL` and `COUNT` must agree (everything per-class is sized
// by COUNT and iterated via ALL), and the dense indices must fit the
// `ClassMask` u8.  Both checked at compile time.
const _: () = assert!(JobClass::ALL.len() == JobClass::COUNT);
const _: () = assert!(JobClass::COUNT <= 8, "ClassMask is a u8 bit-set");

/// Queue items the scheduler can classify (dense [`JobClass`] index).
/// Lives next to [`JobClass`] so the per-class queue bank
/// ([`crate::cluster::QueueBank`]), the thief, and the simulators all
/// speak one classification without depending on the runtime job type.
pub trait Classed {
    fn class_index(&self) -> usize;
}

/// Plain integers classify as CONV-tile work (tests and simulators).
impl Classed for u32 {
    fn class_index(&self) -> usize {
        0
    }
}

impl Classed for u64 {
    fn class_index(&self) -> usize {
        0
    }
}

/// Bit-set of job classes: the capability metadata of an accelerator
/// backend.  Per-cluster scheduling uses the *union* over a cluster's
/// members (which classes the cluster can accept — some member will serve
/// them), never the intersection: member-level masks decide who pops what.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClassMask(u8);

impl ClassMask {
    /// Supports nothing.
    pub const NONE: ClassMask = ClassMask(0);

    /// Supports every job class — derived from [`JobClass::ALL`] (with
    /// the length const-asserted against `COUNT`), so adding a class
    /// cannot silently leave it out of the full mask.
    pub const ALL: ClassMask = {
        let mut bits = 0u8;
        let mut i = 0;
        while i < JobClass::ALL.len() {
            bits |= 1 << JobClass::ALL[i].index();
            i += 1;
        }
        ClassMask(bits)
    };

    /// Exactly the int8 quantized classes — the capability bits a
    /// backend claims (or is denied) for quantized inference.
    pub const Q8: ClassMask = {
        let mut bits = 0u8;
        let mut i = 0;
        while i < JobClass::ALL.len() {
            if JobClass::ALL[i].is_q8() {
                bits |= 1 << JobClass::ALL[i].index();
            }
            i += 1;
        }
        ClassMask(bits)
    };

    /// Supports every job class (alias of [`ClassMask::ALL`], kept as a
    /// function for the many existing call sites).
    pub const fn all() -> ClassMask {
        ClassMask::ALL
    }

    /// Supports exactly `classes`.
    pub fn of(classes: &[JobClass]) -> ClassMask {
        ClassMask(classes.iter().fold(0u8, |m, c| m | (1 << c.index())))
    }

    pub fn supports(self, class: JobClass) -> bool {
        self.supports_index(class.index())
    }

    /// Same as [`ClassMask::supports`] via a dense index (the thief works
    /// on indices to stay generic over queue item types).
    pub fn supports_index(self, index: usize) -> bool {
        index < JobClass::COUNT && self.0 & (1 << index) != 0
    }

    pub fn intersect(self, other: ClassMask) -> ClassMask {
        ClassMask(self.0 & other.0)
    }

    /// This mask minus `class` (the thief's class-level ship gate prunes
    /// steal masks with it).
    pub fn without(self, class: JobClass) -> ClassMask {
        ClassMask(self.0 & !(1 << class.index()))
    }

    pub fn union(self, other: ClassMask) -> ClassMask {
        ClassMask(self.0 | other.0)
    }

    /// Raw bit pattern (dense, `< 1 << JobClass::COUNT`).  Queue banks use
    /// it to key per-mask round-robin cursors.
    pub fn bits(self) -> u8 {
        self.0
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    /// The classes in this mask, in dense-index order.
    pub fn classes(self) -> impl Iterator<Item = JobClass> {
        JobClass::ALL.into_iter().filter(move |c| self.supports(*c))
    }
}

impl Classed for Job {
    fn class_index(&self) -> usize {
        self.class().index()
    }
}

/// Job metadata (the paper's `job_t` minus the raw pointers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobDesc {
    /// Globally unique id (assigned by the job generator).
    pub job_id: u64,
    /// Index of the owning layer within the network ("layer_id").
    pub layer_id: usize,
    /// Which input frame this job belongs to.
    pub frame_id: u64,
    /// Output tile coordinates ("t1", "t2"); (0,0) for whole-matrix jobs.
    pub t1: usize,
    pub t2: usize,
    /// Matrix geometry ("m", "n", "k" of the paper's struct).  For
    /// [`JobClass::Im2col`] jobs the grid describes the *produced* matrix
    /// (M=C·K², P=OH·OW) with a dummy inner dimension of 1.
    pub grid: TileGrid,
}

impl JobDesc {
    /// Inner-tile count this job iterates (K of the job kernel).
    pub fn k_tiles(&self) -> usize {
        self.grid.k_tiles()
    }

    /// Nominal FLOPs of this job (padded tiles: 2·TS²·K·TS).
    pub fn flops(&self) -> u64 {
        let ts = self.grid.ts as u64;
        2 * ts * ts * ts * self.k_tiles() as u64
    }

    /// Bytes moved per job: fetch 2·K tiles + write back one (f32).
    pub fn bytes_moved(&self) -> u64 {
        let tile_bytes = (self.grid.ts * self.grid.ts * 4) as u64;
        (2 * self.k_tiles() as u64 + 1) * tile_bytes
    }
}

/// The operand payload of a job, one variant per [`JobClass`].  Every
/// operand is an [`OperandView`] — cloning a job bumps refcounts, it never
/// copies data.
#[derive(Debug, Clone)]
pub enum JobKind {
    /// CONV tile GEMM over **pre-packed** operand panels: `a_tiles` is the
    /// K (TS,TS) row-panel of A for this job's t1, `b_tiles` the K (TS,TS)
    /// column-panel of B for its t2 — each a `k_tiles·TS²` view into the
    /// layer's packed operand buffers (the weight prepack / the frame
    /// arena).  The job IS the paper's fetch set; executing it fetches
    /// nothing.
    ConvTile {
        a_tiles: OperandView,
        b_tiles: OperandView,
    },
    /// FC GEMM: A = weights (M×N), B = one activation column (N×1).
    /// [`Job::fc`] rejects B ≠ one column so a batched operand cannot slip
    /// through the single-column path silently — batched FC has its own
    /// variant below.
    FcGemm { a: OperandView, b: OperandView },
    /// Fused batched FC GEMM: A = weights (M×N), B = the row-major (N,B)
    /// operand holding one activation **column per request** (element
    /// `(k, j)` is request j's k-th activation — [`pack_fc_columns`]
    /// builds it, NOT a concatenation of per-request rows).  The result
    /// (M,B) is scattered back per request with [`unpack_fc_columns`].
    FcGemmBatch { a: OperandView, b: OperandView },
    /// im2col lowering of one (C,H,W) input into the (C·K², OH·OW) matrix.
    Im2col {
        input: OperandView,
        chw: (usize, usize, usize),
        size: usize,
        stride: usize,
        pad: usize,
    },
    /// Int8 CONV tile GEMM: the same pre-packed panel discipline as
    /// [`JobKind::ConvTile`], but the panels hold symmetric-quantized i8
    /// codes and `scale` is the product of the two operands' scales
    /// (s_w·s_x).  The kernel accumulates in i32 and the result is
    /// dequantized to f32 at the tile boundary: `c = scale · Σ a·b`.
    ConvTileQ8 {
        a_tiles: OperandView<i8>,
        b_tiles: OperandView<i8>,
        scale: f32,
    },
    /// Int8 FC GEMM: A = quantized weights (M×N), B = one quantized
    /// activation column (N×1), `scale` = s_w·s_x.
    FcGemmQ8 {
        a: OperandView<i8>,
        b: OperandView<i8>,
        scale: f32,
    },
    /// Int8 fused batched FC GEMM over the (N,B) column-packed quantized
    /// operand; `scale` = s_w·s_x shared by the whole batch.
    FcGemmBatchQ8 {
        a: OperandView<i8>,
        b: OperandView<i8>,
        scale: f32,
    },
}

impl JobKind {
    pub fn class(&self) -> JobClass {
        match self {
            JobKind::ConvTile { .. } => JobClass::ConvTile,
            JobKind::FcGemm { .. } => JobClass::FcGemm,
            JobKind::Im2col { .. } => JobClass::Im2col,
            JobKind::FcGemmBatch { .. } => JobClass::FcGemmBatch,
            JobKind::ConvTileQ8 { .. } => JobClass::ConvTileQ8,
            JobKind::FcGemmQ8 { .. } => JobClass::FcGemmQ8,
            JobKind::FcGemmBatchQ8 { .. } => JobClass::FcGemmBatchQ8,
        }
    }
}

/// A dispatchable job: metadata + operand payload + an optional routing
/// hint.
#[derive(Debug, Clone)]
pub struct Job {
    pub desc: JobDesc,
    pub kind: JobKind,
    /// Preferred cluster (the static mapper's CONV placement).  A routing
    /// hint only — the dispatcher falls back to least-loaded routing when
    /// the preferred cluster cannot accept the class.  Never serialized.
    pub placement: Option<usize>,
}

/// Result of executing a job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub desc: JobDesc,
    /// Output buffer: a (TS,TS) row-major tile for CONV-tile jobs, the
    /// dense (M,P) result matrix for FC-GEMM and im2col jobs.
    pub data: Vec<f32>,
}

impl Job {
    /// Class tag of this job (per-class accounting + capability routing).
    pub fn class(&self) -> JobClass {
        self.kind.class()
    }

    /// Service-cost estimate in k-steps (one k-step = one (TS,TS)·(TS,TS)
    /// tile MAC pass).  CONV tiles iterate K inner tiles; an FC GEMM does
    /// the whole tiled iteration space in one job; a fused batch costs its
    /// single-column cost × B (columns share the padded row/K tiling but
    /// each adds a full MAC pass); im2col is a data movement pass, charged
    /// a flat single step.
    pub fn ksteps(&self) -> u64 {
        match self.kind.class() {
            JobClass::ConvTile | JobClass::ConvTileQ8 => self.desc.k_tiles() as u64,
            JobClass::FcGemm | JobClass::FcGemmQ8 => {
                (self.desc.grid.num_jobs() * self.desc.k_tiles()) as u64
            }
            JobClass::FcGemmBatch | JobClass::FcGemmBatchQ8 => {
                (self.desc.grid.rows() * self.desc.k_tiles() * self.desc.grid.p) as u64
            }
            JobClass::Im2col => 1,
        }
    }

    /// Attach a preferred-cluster routing hint (builder style).
    pub fn placed(mut self, cluster: Option<usize>) -> Job {
        self.placement = cluster;
        self
    }

    /// Build one FC-GEMM job: y(M) = W(M×N)·x(N).  See
    /// [`JobKind::FcGemm`] for why x must be exactly one activation
    /// column.
    #[allow(clippy::too_many_arguments)]
    pub fn fc(
        job_id: u64,
        layer_id: usize,
        frame_id: u64,
        out_n: usize,
        in_n: usize,
        w: impl Into<OperandView>,
        x: impl Into<OperandView>,
        ts: usize,
    ) -> Job {
        let (w, x) = (w.into(), x.into());
        assert_eq!(w.len(), out_n * in_n, "FC weight size mismatch");
        assert_eq!(
            x.len(),
            in_n,
            "FC activation must be one (N,) column (batched B needs the \
             column-major fusion layout; see ROADMAP)"
        );
        Job {
            desc: JobDesc {
                job_id,
                layer_id,
                frame_id,
                t1: 0,
                t2: 0,
                grid: TileGrid::new(out_n, in_n, 1, ts),
            },
            kind: JobKind::FcGemm { a: w, b: x },
            placement: None,
        }
    }

    /// Build one fused batched-FC job: Y(M,B) = W(M×N)·X(N,B), where `xb`
    /// is the row-major (N,B) operand of [`pack_fc_columns`] — one
    /// activation column per request.  `frame_id` tags the batch (by
    /// convention the first fused request's frame).
    #[allow(clippy::too_many_arguments)]
    pub fn fc_batch(
        job_id: u64,
        layer_id: usize,
        frame_id: u64,
        out_n: usize,
        in_n: usize,
        batch: usize,
        w: impl Into<OperandView>,
        xb: impl Into<OperandView>,
        ts: usize,
    ) -> Job {
        let (w, xb) = (w.into(), xb.into());
        assert!(batch >= 1, "fused FC batch must hold at least one column");
        assert_eq!(w.len(), out_n * in_n, "FC weight size mismatch");
        assert_eq!(
            xb.len(),
            in_n * batch,
            "batched FC operand must be (IN, B) — see pack_fc_columns"
        );
        Job {
            desc: JobDesc {
                job_id,
                layer_id,
                frame_id,
                t1: 0,
                t2: 0,
                grid: TileGrid::new(out_n, in_n, batch, ts),
            },
            kind: JobKind::FcGemmBatch { a: w, b: xb },
            placement: None,
        }
    }

    /// Build one int8 FC-GEMM job: y(M) = scale · (Wq(M×N)·xq(N)) with i8
    /// operands and i32 accumulation.  Same single-column contract as
    /// [`Job::fc`].
    #[allow(clippy::too_many_arguments)]
    pub fn fc_q8(
        job_id: u64,
        layer_id: usize,
        frame_id: u64,
        out_n: usize,
        in_n: usize,
        w: impl Into<OperandView<i8>>,
        x: impl Into<OperandView<i8>>,
        scale: f32,
        ts: usize,
    ) -> Job {
        let (w, x) = (w.into(), x.into());
        assert_eq!(w.len(), out_n * in_n, "FC weight size mismatch");
        assert_eq!(
            x.len(),
            in_n,
            "FC activation must be one (N,) column (batched B needs the \
             column-major fusion layout; see ROADMAP)"
        );
        Job {
            desc: JobDesc {
                job_id,
                layer_id,
                frame_id,
                t1: 0,
                t2: 0,
                grid: TileGrid::new(out_n, in_n, 1, ts),
            },
            kind: JobKind::FcGemmQ8 { a: w, b: x, scale },
            placement: None,
        }
    }

    /// Build one int8 fused batched-FC job over a column-packed (N,B)
    /// quantized operand ([`pack_fc_columns_q8`]); `scale` = s_w·s_x.
    #[allow(clippy::too_many_arguments)]
    pub fn fc_batch_q8(
        job_id: u64,
        layer_id: usize,
        frame_id: u64,
        out_n: usize,
        in_n: usize,
        batch: usize,
        w: impl Into<OperandView<i8>>,
        xb: impl Into<OperandView<i8>>,
        scale: f32,
        ts: usize,
    ) -> Job {
        let (w, xb) = (w.into(), xb.into());
        assert!(batch >= 1, "fused FC batch must hold at least one column");
        assert_eq!(w.len(), out_n * in_n, "FC weight size mismatch");
        assert_eq!(
            xb.len(),
            in_n * batch,
            "batched FC operand must be (IN, B) — see pack_fc_columns"
        );
        Job {
            desc: JobDesc {
                job_id,
                layer_id,
                frame_id,
                t1: 0,
                t2: 0,
                grid: TileGrid::new(out_n, in_n, batch, ts),
            },
            kind: JobKind::FcGemmBatchQ8 { a: w, b: xb, scale },
            placement: None,
        }
    }

    /// Build one im2col job lowering a (C,H,W) input for a `size`×`size`
    /// convolution with `stride`/`pad`.
    #[allow(clippy::too_many_arguments)]
    pub fn im2col(
        job_id: u64,
        layer_id: usize,
        frame_id: u64,
        chw: (usize, usize, usize),
        size: usize,
        stride: usize,
        pad: usize,
        input: impl Into<OperandView>,
        ts: usize,
    ) -> Job {
        let input = input.into();
        let (c, h, w) = chw;
        assert_eq!(input.len(), c * h * w, "im2col input size mismatch");
        let (oh, ow) = crate::nn::conv_out_hw(h, w, size, stride, pad);
        Job {
            desc: JobDesc {
                job_id,
                layer_id,
                frame_id,
                t1: 0,
                t2: 0,
                grid: TileGrid::new(c * size * size, 1, oh * ow, ts),
            },
            kind: JobKind::Im2col {
                input,
                chw,
                size,
                stride,
                pad,
            },
            placement: None,
        }
    }

    /// A CONV-tile job's packed operand panels — the (K,TS,TS) fetch set
    /// the PE kernel consumes (steps ①–② of Listing 3), already resident
    /// in the job's views: no copy, just two slices.  Panics on non-CONV
    /// jobs (the PE kernel only speaks tiles; capability routing keeps
    /// other classes away from it).
    pub fn tile_operands(&self) -> (&[f32], &[f32]) {
        match &self.kind {
            JobKind::ConvTile { a_tiles, b_tiles } => (a_tiles, b_tiles),
            // Spelled out (no `_` arm) so adding a job class forces this
            // dispatch decision instead of silently inheriting the panic.
            JobKind::FcGemm { .. }
            | JobKind::FcGemmBatch { .. }
            | JobKind::Im2col { .. }
            | JobKind::ConvTileQ8 { .. }
            | JobKind::FcGemmQ8 { .. }
            | JobKind::FcGemmBatchQ8 { .. } => {
                panic!("tile_operands on a {:?} job", self.class())
            }
        }
    }

    /// A quantized CONV-tile job's packed i8 operand panels plus the
    /// dequantization scale — the Q8 twin of [`Job::tile_operands`].
    /// Panics on every other class.
    pub fn tile_operands_q8(&self) -> (&[i8], &[i8], f32) {
        match &self.kind {
            JobKind::ConvTileQ8 {
                a_tiles,
                b_tiles,
                scale,
            } => (a_tiles, b_tiles, *scale),
            JobKind::ConvTile { .. }
            | JobKind::FcGemm { .. }
            | JobKind::FcGemmBatch { .. }
            | JobKind::Im2col { .. }
            | JobKind::FcGemmQ8 { .. }
            | JobKind::FcGemmBatchQ8 { .. } => {
                panic!("tile_operands_q8 on a {:?} job", self.class())
            }
        }
    }

    /// Execute on the native (NEON-path) kernels.
    pub fn execute_native(&self) -> JobResult {
        let data = match &self.kind {
            JobKind::ConvTile { a_tiles, b_tiles } => {
                job_mm_native(a_tiles, b_tiles, self.desc.k_tiles(), self.desc.grid.ts)
            }
            // Single-column and fused-batch FC share one kernel: the fused
            // operand just widens P from 1 to B, so each output element
            // accumulates in exactly the per-sample order (bit-identical
            // to running the B columns one at a time).
            JobKind::FcGemm { a, b } | JobKind::FcGemmBatch { a, b } => {
                let g = self.desc.grid;
                let mut c = vec![0.0f32; g.m * g.p];
                super::gemm::gemm_blocked_into(a, b, &mut c, g.m, g.n, g.p);
                c
            }
            JobKind::Im2col {
                input,
                chw,
                size,
                stride,
                pad,
            } => crate::nn::im2col::im2col_slice(input, *chw, *size, *stride, *pad),
            JobKind::ConvTileQ8 {
                a_tiles,
                b_tiles,
                scale,
            } => super::tile::job_mm_q8_native(
                a_tiles,
                b_tiles,
                self.desc.k_tiles(),
                self.desc.grid.ts,
                *scale,
            ),
            // Like their f32 twins, single-column and fused-batch share
            // one kernel; the i32 accumulator makes the integer part
            // exact, so the only rounding is the final per-element
            // `scale · acc` dequantization.
            JobKind::FcGemmQ8 { a, b, scale } | JobKind::FcGemmBatchQ8 { a, b, scale } => {
                let g = self.desc.grid;
                let mut acc = vec![0i32; g.m * g.p];
                super::gemm::gemm_q8_blocked_into(a, b, &mut acc, g.m, g.n, g.p);
                acc.iter().map(|&v| v as f32 * *scale).collect()
            }
        };
        JobResult {
            desc: self.desc,
            data,
        }
    }
}

/// Generate all CONV-tile jobs of one GEMM from DENSE (M×N) / (N×P)
/// operands: packs each operand into the blocked layout exactly once,
/// then slices per-job views out of the two packs (the per-job fetch of
/// the old operand plane is gone).  `next_job_id` provides
/// globally-unique ids across layers/frames.
pub fn jobs_for_gemm(
    layer_id: usize,
    frame_id: u64,
    grid: TileGrid,
    a: impl Into<OperandView>,
    b: impl Into<OperandView>,
    next_job_id: &mut u64,
) -> Vec<Job> {
    let (a, b) = (a.into(), b.into());
    assert_eq!(a.len(), grid.m * grid.n, "A operand size mismatch");
    assert_eq!(b.len(), grid.n * grid.p, "B operand size mismatch");
    let a_pack = OperandView::from(grid.pack_a_tiles(&a));
    let b_pack = OperandView::from(grid.pack_b_tiles(&b));
    jobs_from_packs(layer_id, frame_id, grid, a_pack, b_pack, next_job_id)
}

/// Generate all CONV-tile jobs of one GEMM from operands ALREADY in the
/// blocked layout ([`TileGrid::pack_a_tiles`] / [`TileGrid::pack_b_tiles`]):
/// every job's operands are offset/length views into the two packs — zero
/// copies, shared `Arc` backing.  This is the hot-path entry: the network's
/// load-time weight prepack and the frame arena's packed im2col panels go
/// straight in.
pub fn jobs_from_packs(
    layer_id: usize,
    frame_id: u64,
    grid: TileGrid,
    a_pack: OperandView,
    b_pack: OperandView,
    next_job_id: &mut u64,
) -> Vec<Job> {
    let panel = grid.panel_elems();
    assert_eq!(a_pack.len(), grid.rows() * panel, "packed A size mismatch");
    assert_eq!(b_pack.len(), grid.cols() * panel, "packed B size mismatch");
    let mut jobs = Vec::with_capacity(grid.num_jobs());
    for (t1, t2) in grid.tiles() {
        let desc = JobDesc {
            job_id: *next_job_id,
            layer_id,
            frame_id,
            t1,
            t2,
            grid,
        };
        *next_job_id += 1;
        jobs.push(Job {
            desc,
            kind: JobKind::ConvTile {
                a_tiles: a_pack.slice(t1 * panel, panel),
                b_tiles: b_pack.slice(t2 * panel, panel),
            },
            placement: None,
        });
    }
    jobs
}

/// The Q8 twin of [`jobs_from_packs`]: generate all quantized CONV-tile
/// jobs of one GEMM from i8 operand packs already in the blocked layout
/// (quantized element-wise from the f32 packs, so panel geometry is
/// identical).  `scale` is the shared s_w·s_x dequantization factor.
pub fn jobs_from_packs_q8(
    layer_id: usize,
    frame_id: u64,
    grid: TileGrid,
    a_pack: OperandView<i8>,
    b_pack: OperandView<i8>,
    scale: f32,
    next_job_id: &mut u64,
) -> Vec<Job> {
    let panel = grid.panel_elems();
    assert_eq!(a_pack.len(), grid.rows() * panel, "packed A size mismatch");
    assert_eq!(b_pack.len(), grid.cols() * panel, "packed B size mismatch");
    let mut jobs = Vec::with_capacity(grid.num_jobs());
    for (t1, t2) in grid.tiles() {
        let desc = JobDesc {
            job_id: *next_job_id,
            layer_id,
            frame_id,
            t1,
            t2,
            grid,
        };
        *next_job_id += 1;
        jobs.push(Job {
            desc,
            kind: JobKind::ConvTileQ8 {
                a_tiles: a_pack.slice(t1 * panel, panel),
                b_tiles: b_pack.slice(t2 * panel, panel),
                scale,
            },
            placement: None,
        });
    }
    jobs
}

/// Pack B equal-length activation vectors into the row-major (IN, B)
/// operand of a fused batched-FC GEMM: `packed[k*B + j] = cols[j][k]`
/// (request j is column j).  The inverse is [`unpack_fc_columns`].
pub fn pack_fc_columns(cols: &[&[f32]]) -> Vec<f32> {
    let batch = cols.len();
    assert!(batch >= 1, "cannot pack an empty batch");
    let in_n = cols[0].len();
    let mut packed = vec![0.0f32; in_n * batch];
    for (j, col) in cols.iter().enumerate() {
        assert_eq!(col.len(), in_n, "fused FC columns must share one length");
        for (k, v) in col.iter().enumerate() {
            packed[k * batch + j] = *v;
        }
    }
    super::operand::note_copy(packed.len() * 4);
    packed
}

/// The Q8 twin of [`pack_fc_columns`]: pack B equal-length quantized
/// activation columns into the row-major (IN, B) i8 operand of a fused
/// batched Q8 FC GEMM (`packed[k*B + j] = cols[j][k]`).
pub fn pack_fc_columns_q8(cols: &[&[i8]]) -> Vec<i8> {
    let batch = cols.len();
    assert!(batch >= 1, "cannot pack an empty batch");
    let in_n = cols[0].len();
    let mut packed = vec![0i8; in_n * batch];
    for (j, col) in cols.iter().enumerate() {
        assert_eq!(col.len(), in_n, "fused FC columns must share one length");
        for (k, v) in col.iter().enumerate() {
            packed[k * batch + j] = *v;
        }
    }
    super::operand::note_copy(packed.len());
    packed
}

/// Split the row-major (OUT, B) result of a fused batched-FC job back into
/// per-request output columns (`out[j][i] = c[i*B + j]`).
pub fn unpack_fc_columns(c: &[f32], out_n: usize, batch: usize) -> Vec<Vec<f32>> {
    assert_eq!(c.len(), out_n * batch, "fused FC result size mismatch");
    (0..batch)
        .map(|j| (0..out_n).map(|i| c[i * batch + j]).collect())
        .collect()
}

/// Assemble CONV-tile job results back into the dense C matrix (M×P).
pub fn gather_results(grid: TileGrid, results: &[JobResult]) -> Vec<f32> {
    assert_eq!(results.len(), grid.num_jobs(), "missing job results");
    let mut c = vec![0.0f32; grid.m * grid.p];
    for r in results {
        grid.scatter_c(&mut c, r.desc.t1, r.desc.t2, &r.data);
    }
    c
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::mm::gemm::gemm_naive;
    use crate::tensor::Tensor;
    use crate::util::rng::XorShift64Star;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        XorShift64Star::new(seed).fill_f32(n, 2.0)
    }

    #[test]
    fn jobs_cover_grid_exactly_once() {
        let grid = TileGrid::new(70, 40, 90, 32);
        let a = Arc::new(rand_vec(70 * 40, 1));
        let b = Arc::new(rand_vec(40 * 90, 2));
        let mut id = 0;
        let jobs = jobs_for_gemm(3, 7, grid, a, b, &mut id);
        assert_eq!(jobs.len(), grid.num_jobs());
        assert_eq!(id, jobs.len() as u64);
        let mut seen = std::collections::HashSet::new();
        for j in &jobs {
            assert!(seen.insert((j.desc.t1, j.desc.t2)), "duplicate tile");
            assert_eq!(j.class(), JobClass::ConvTile);
            assert_eq!(j.desc.layer_id, 3);
            assert_eq!(j.desc.frame_id, 7);
            assert!(j.desc.t1 < grid.rows() && j.desc.t2 < grid.cols());
        }
    }

    #[test]
    fn execute_and_gather_matches_gemm() {
        let grid = TileGrid::new(50, 70, 45, 32);
        let av = rand_vec(50 * 70, 3);
        let bv = rand_vec(70 * 45, 4);
        let a = Arc::new(av.clone());
        let b = Arc::new(bv.clone());
        let mut id = 0;
        let jobs = jobs_for_gemm(0, 0, grid, a, b, &mut id);
        let results: Vec<JobResult> = jobs.iter().map(|j| j.execute_native()).collect();
        let c = gather_results(grid, &results);
        let want = gemm_naive(
            &Tensor::from_vec(&[50, 70], av),
            &Tensor::from_vec(&[70, 45], bv),
        );
        let got = Tensor::from_vec(&[50, 45], c);
        assert!(want.allclose(&got, 1e-4, 1e-4), "{}", want.max_abs_diff(&got));
    }

    #[test]
    fn fc_job_matches_dense_gemm() {
        let (out_n, in_n) = (37, 83);
        let wv = rand_vec(out_n * in_n, 5);
        let xv = rand_vec(in_n, 6);
        let job = Job::fc(
            9,
            4,
            2,
            out_n,
            in_n,
            Arc::new(wv.clone()),
            Arc::new(xv.clone()),
            32,
        );
        assert_eq!(job.class(), JobClass::FcGemm);
        assert!(job.ksteps() >= 1);
        let got = job.execute_native();
        assert_eq!(got.desc.job_id, 9);
        let want = gemm_naive(
            &Tensor::from_vec(&[out_n, in_n], wv),
            &Tensor::from_vec(&[in_n, 1], xv),
        );
        let got_t = Tensor::from_vec(&[out_n, 1], got.data);
        assert!(want.allclose(&got_t, 1e-4, 1e-4));
    }

    #[test]
    fn fused_fc_batch_matches_per_sample_jobs_bitwise() {
        let (out_n, in_n, batch) = (37, 83, 5);
        let w = Arc::new(rand_vec(out_n * in_n, 11));
        let xs: Vec<Vec<f32>> = (0..batch)
            .map(|j| rand_vec(in_n, 20 + j as u64))
            .collect();
        let cols: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        let packed = pack_fc_columns(&cols);
        assert_eq!(packed.len(), in_n * batch);
        // Column j of the packed operand is request j's activation.
        assert_eq!(packed[3 * batch + 2], xs[2][3]);

        let fused = Job::fc_batch(
            0,
            4,
            2,
            out_n,
            in_n,
            batch,
            Arc::clone(&w),
            Arc::new(packed),
            32,
        );
        assert_eq!(fused.class(), JobClass::FcGemmBatch);
        // One fused job costs B single-column jobs' worth of k-steps.
        let single = Job::fc(
            1,
            4,
            2,
            out_n,
            in_n,
            Arc::clone(&w),
            Arc::new(xs[0].clone()),
            32,
        );
        assert_eq!(fused.ksteps(), single.ksteps() * batch as u64);

        let got = unpack_fc_columns(&fused.execute_native().data, out_n, batch);
        for (j, x) in xs.iter().enumerate() {
            let want = Job::fc(
                2 + j as u64,
                4,
                2,
                out_n,
                in_n,
                Arc::clone(&w),
                Arc::new(x.clone()),
                32,
            )
            .execute_native();
            // Bit-identical: the fused kernel accumulates each output
            // element in the exact per-sample order.
            assert_eq!(got[j], want.data, "request {j}");
        }
    }

    #[test]
    #[should_panic(expected = "(IN, B)")]
    fn fc_batch_rejects_wrong_operand_size() {
        let _ = Job::fc_batch(
            0,
            0,
            0,
            4,
            4,
            2,
            Arc::new(vec![0.0; 16]),
            Arc::new(vec![0.0; 4]),
            4,
        );
    }

    #[test]
    fn im2col_job_matches_direct_lowering() {
        let (c, h, w) = (3, 9, 8);
        let xv = rand_vec(c * h * w, 7);
        let x = Tensor::from_vec(&[c, h, w], xv.clone());
        let job = Job::im2col(1, 0, 0, (c, h, w), 3, 1, 1, Arc::new(xv), 32);
        assert_eq!(job.class(), JobClass::Im2col);
        assert_eq!(job.ksteps(), 1);
        let got = job.execute_native();
        let want = crate::nn::im2col::im2col(&x, 3, 1, 1);
        assert_eq!(got.data, want.data());
        assert_eq!(got.data.len(), job.desc.grid.m * job.desc.grid.p);
    }

    #[test]
    fn class_mask_capabilities() {
        let all = ClassMask::all();
        for c in JobClass::ALL {
            assert!(all.supports(c));
        }
        let conv_only = ClassMask::of(&[JobClass::ConvTile]);
        assert!(conv_only.supports(JobClass::ConvTile));
        assert!(!conv_only.supports(JobClass::FcGemm));
        assert!(!conv_only.supports(JobClass::Im2col));
        assert_eq!(all.intersect(conv_only), conv_only);
        assert_eq!(conv_only.intersect(ClassMask::NONE), ClassMask::NONE);
        assert!(!ClassMask::all().supports_index(JobClass::COUNT));
        // Union algebra (per-cluster accept masks are member unions).
        let fc_only = ClassMask::of(&[JobClass::FcGemm]);
        let both = conv_only.union(fc_only);
        assert!(both.supports(JobClass::ConvTile) && both.supports(JobClass::FcGemm));
        assert!(!both.supports(JobClass::Im2col));
        assert_eq!(ClassMask::NONE.union(all), all);
        assert!(ClassMask::NONE.is_empty() && !all.is_empty());
        assert_eq!(both.without(JobClass::FcGemm), conv_only);
        assert_eq!(conv_only.without(JobClass::Im2col), conv_only);
        assert!(conv_only.without(JobClass::ConvTile).is_empty());
        assert_eq!(
            both.classes().collect::<Vec<_>>(),
            vec![JobClass::ConvTile, JobClass::FcGemm]
        );
    }

    #[test]
    fn flops_and_bytes_accounting() {
        let grid = TileGrid::new(32, 96, 32, 32);
        let desc = JobDesc {
            job_id: 0,
            layer_id: 0,
            frame_id: 0,
            t1: 0,
            t2: 0,
            grid,
        };
        assert_eq!(desc.k_tiles(), 3);
        assert_eq!(desc.flops(), 2 * 32 * 32 * 32 * 3);
        assert_eq!(desc.bytes_moved(), (2 * 3 + 1) * 32 * 32 * 4);
    }

    #[test]
    #[should_panic(expected = "A operand size mismatch")]
    fn operand_size_checked() {
        let grid = TileGrid::new(4, 4, 4, 4);
        let mut id = 0;
        jobs_for_gemm(0, 0, grid, Arc::new(vec![0.0; 3]), Arc::new(vec![0.0; 16]), &mut id);
    }

    #[test]
    #[should_panic(expected = "missing job results")]
    fn gather_requires_all_results() {
        let grid = TileGrid::new(64, 32, 64, 32);
        gather_results(grid, &[]);
    }

    #[test]
    #[should_panic(expected = "tile_operands")]
    fn tile_operands_rejects_non_conv_jobs() {
        let job = Job::fc(0, 0, 0, 4, 4, Arc::new(vec![0.0; 16]), Arc::new(vec![0.0; 4]), 4);
        let _ = job.tile_operands();
    }

    /// The zero-copy contract at the job level: every job generated from
    /// pre-packed operands carries views that ALIAS the two packs (shared
    /// `Arc`, offset arithmetic only), and cloning a job copies nothing.
    #[test]
    fn jobs_from_packs_alias_the_packs() {
        let grid = TileGrid::new(70, 40, 90, 32);
        let a = rand_vec(70 * 40, 41);
        let b = rand_vec(40 * 90, 42);
        let a_pack = OperandView::from(grid.pack_a_tiles(&a));
        let b_pack = OperandView::from(grid.pack_b_tiles(&b));
        let panel = grid.panel_elems();
        let mut id = 0;
        let jobs = jobs_from_packs(5, 9, grid, a_pack.clone(), b_pack.clone(), &mut id);
        assert_eq!(jobs.len(), grid.num_jobs());
        for job in &jobs {
            let (at, bt) = job.tile_operands();
            assert_eq!(at.len(), panel);
            assert_eq!(bt.len(), panel);
            match &job.kind {
                JobKind::ConvTile { a_tiles, b_tiles } => {
                    assert!(Arc::ptr_eq(a_tiles.buffer(), a_pack.buffer()));
                    assert!(Arc::ptr_eq(b_tiles.buffer(), b_pack.buffer()));
                    assert_eq!(a_tiles.offset(), job.desc.t1 * panel);
                    assert_eq!(b_tiles.offset(), job.desc.t2 * panel);
                    // A clone still aliases — refcount bump, no bytes.
                    let cloned = job.clone();
                    let (cat, _) = cloned.tile_operands();
                    assert_eq!(cat.as_ptr(), at.as_ptr());
                }
                _ => unreachable!(),
            }
        }
        // And the dense-operand wrapper produces the identical numbers.
        let results: Vec<JobResult> = jobs.iter().map(|j| j.execute_native()).collect();
        let c = gather_results(grid, &results);
        let mut id2 = 0;
        let dense = jobs_for_gemm(5, 9, grid, a.clone(), b.clone(), &mut id2);
        let dense_results: Vec<JobResult> = dense.iter().map(|j| j.execute_native()).collect();
        assert_eq!(c, gather_results(grid, &dense_results));
    }

    fn rand_q8(n: usize, seed: u64) -> Vec<i8> {
        // Deterministic small codes spanning the i8 range.
        (0..n)
            .map(|i| (((i as u64 * 37 + seed * 13 + 11) % 255) as i64 - 127) as i8)
            .collect()
    }

    #[test]
    fn q8_masks_and_costs_derive_from_the_class_table() {
        // ALL covers every class (including the Q8 trio) and nothing else.
        for c in JobClass::ALL {
            assert!(ClassMask::ALL.supports(c), "{c:?} missing from ALL");
        }
        assert_eq!(ClassMask::ALL, ClassMask::all());
        assert_eq!(ClassMask::ALL.bits().count_ones() as usize, JobClass::COUNT);
        // Q8 is exactly the quantized trio.
        assert_eq!(
            ClassMask::Q8.classes().collect::<Vec<_>>(),
            vec![
                JobClass::ConvTileQ8,
                JobClass::FcGemmQ8,
                JobClass::FcGemmBatchQ8
            ]
        );
        assert_eq!(ClassMask::ALL.intersect(ClassMask::Q8), ClassMask::Q8);
        for c in JobClass::ALL {
            assert_eq!(ClassMask::Q8.supports(c), c.is_q8());
            assert!(c.default_steal_cost() > 0.0);
            assert!(!c.label().is_empty());
        }
        // Q8 classes cost half their f32 twin in the steal policy.
        assert_eq!(
            JobClass::ConvTileQ8.default_steal_cost(),
            JobClass::ConvTile.default_steal_cost() / 2.0
        );
        assert_eq!(
            JobClass::FcGemmBatchQ8.default_steal_cost(),
            JobClass::FcGemmBatch.default_steal_cost() / 2.0
        );
    }

    #[test]
    fn fc_q8_matches_integer_oracle_exactly() {
        let (out_n, in_n) = (13, 57);
        let w = rand_q8(out_n * in_n, 1);
        let x = rand_q8(in_n, 2);
        let scale = 0.037f32;
        let job = Job::fc_q8(
            7,
            3,
            1,
            out_n,
            in_n,
            Arc::new(w.clone()),
            Arc::new(x.clone()),
            scale,
            32,
        );
        assert_eq!(job.class(), JobClass::FcGemmQ8);
        let got = job.execute_native();
        assert_eq!(got.desc.job_id, 7);
        for i in 0..out_n {
            let acc: i64 = (0..in_n)
                .map(|k| w[i * in_n + k] as i64 * x[k] as i64)
                .sum();
            // i32 accumulation is exact here, so the q8 path must equal
            // the integer oracle to the bit after one dequantize multiply.
            assert_eq!(got.data[i], acc as f32 * scale, "row {i}");
        }
    }

    #[test]
    fn fused_fc_batch_q8_matches_per_sample_jobs_bitwise() {
        let (out_n, in_n, batch) = (9, 41, 4);
        let w = Arc::new(rand_q8(out_n * in_n, 5));
        let scale = 0.01f32;
        let xs: Vec<Vec<i8>> = (0..batch).map(|j| rand_q8(in_n, 30 + j as u64)).collect();
        let cols: Vec<&[i8]> = xs.iter().map(|x| x.as_slice()).collect();
        let packed = pack_fc_columns_q8(&cols);
        assert_eq!(packed.len(), in_n * batch);
        assert_eq!(packed[3 * batch + 2], xs[2][3]);
        let fused = Job::fc_batch_q8(
            0,
            1,
            0,
            out_n,
            in_n,
            batch,
            Arc::clone(&w),
            Arc::new(packed),
            scale,
            32,
        );
        assert_eq!(fused.class(), JobClass::FcGemmBatchQ8);
        let got = unpack_fc_columns(&fused.execute_native().data, out_n, batch);
        for (j, x) in xs.iter().enumerate() {
            let want = Job::fc_q8(
                1 + j as u64,
                1,
                0,
                out_n,
                in_n,
                Arc::clone(&w),
                Arc::new(x.clone()),
                scale,
                32,
            )
            .execute_native();
            assert_eq!(got[j], want.data, "request {j}");
        }
    }

    #[test]
    fn q8_ksteps_mirror_their_f32_twins() {
        let w = Arc::new(rand_q8(37 * 83, 1));
        let x = Arc::new(rand_q8(83, 2));
        let q8 = Job::fc_q8(0, 0, 0, 37, 83, Arc::clone(&w), Arc::clone(&x), 1.0, 32);
        let wf = Arc::new(vec![0.0f32; 37 * 83]);
        let xf = Arc::new(vec![0.0f32; 83]);
        let f32_twin = Job::fc(1, 0, 0, 37, 83, wf, xf, 32);
        assert_eq!(q8.ksteps(), f32_twin.ksteps());
    }

    #[test]
    fn jobs_from_packs_q8_alias_the_packs_and_match_the_oracle() {
        let grid = TileGrid::new(50, 70, 45, 32);
        let a = rand_q8(50 * 70, 8);
        let b = rand_q8(70 * 45, 9);
        let scale = 0.125f32;
        // Quantized packs share the f32 pack geometry: quantize the dense
        // operands, pack via the f32 packer on code values, then cast.
        let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let a_packf = grid.pack_a_tiles(&af);
        let b_packf = grid.pack_b_tiles(&bf);
        let a_pack: OperandView<i8> =
            OperandView::from(a_packf.iter().map(|&v| v as i8).collect::<Vec<i8>>());
        let b_pack: OperandView<i8> =
            OperandView::from(b_packf.iter().map(|&v| v as i8).collect::<Vec<i8>>());
        let panel = grid.panel_elems();
        let mut id = 0;
        let jobs =
            jobs_from_packs_q8(2, 4, grid, a_pack.clone(), b_pack.clone(), scale, &mut id);
        assert_eq!(jobs.len(), grid.num_jobs());
        for job in &jobs {
            assert_eq!(job.class(), JobClass::ConvTileQ8);
            let (at, bt, s) = job.tile_operands_q8();
            assert_eq!((at.len(), bt.len(), s), (panel, panel, scale));
            match &job.kind {
                JobKind::ConvTileQ8 {
                    a_tiles, b_tiles, ..
                } => {
                    assert!(Arc::ptr_eq(a_tiles.buffer(), a_pack.buffer()));
                    assert!(Arc::ptr_eq(b_tiles.buffer(), b_pack.buffer()));
                    assert_eq!(a_tiles.offset(), job.desc.t1 * panel);
                    assert_eq!(b_tiles.offset(), job.desc.t2 * panel);
                }
                _ => unreachable!(),
            }
        }
        // Gathered q8 results equal the dense integer oracle · scale.
        let results: Vec<JobResult> = jobs.iter().map(|j| j.execute_native()).collect();
        let c = gather_results(grid, &results);
        for i in 0..grid.m {
            for j in 0..grid.p {
                let acc: i64 = (0..grid.n)
                    .map(|k| a[i * grid.n + k] as i64 * b[k * grid.p + j] as i64)
                    .sum();
                assert_eq!(c[i * grid.p + j], acc as f32 * scale, "({i},{j})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "tile_operands_q8")]
    fn tile_operands_q8_rejects_f32_jobs() {
        let job = Job::fc(0, 0, 0, 4, 4, Arc::new(vec![0.0; 16]), Arc::new(vec![0.0; 4]), 4);
        let _ = job.tile_operands_q8();
    }
}
