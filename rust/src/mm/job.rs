//! The *job* — Synergy's workload granularity (paper Listing 2 / Fig 3).
//!
//! A job is the computation of one (TS,TS) output tile C(t1,t2) of a CONV
//! layer's GEMM.  The struct carries what the paper's job struct carries:
//! operand "base addresses" (shared buffers), the GEMM dimensions, the tile
//! index, and the owning layer id — plus the frame id, since the pipelined
//! design keeps multiple frames in flight (§3.1.1 "inter-frame parallelism").

use std::sync::Arc;

use super::tile::{job_mm_native, TileGrid};

/// Job metadata (the paper's `job_t` minus the raw pointers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobDesc {
    /// Globally unique id (assigned by the job generator).
    pub job_id: u64,
    /// Index of the owning CONV layer within the network ("layer_id").
    pub layer_id: usize,
    /// Which input frame this job belongs to.
    pub frame_id: u64,
    /// Output tile coordinates ("t1", "t2").
    pub t1: usize,
    pub t2: usize,
    /// GEMM geometry ("m", "n", "k" of the paper's struct).
    pub grid: TileGrid,
}

impl JobDesc {
    /// Inner-tile count this job iterates (K of the job kernel).
    pub fn k_tiles(&self) -> usize {
        self.grid.k_tiles()
    }

    /// Nominal FLOPs of this job (padded tiles: 2·TS²·K·TS).
    pub fn flops(&self) -> u64 {
        let ts = self.grid.ts as u64;
        2 * ts * ts * ts * self.k_tiles() as u64
    }

    /// Bytes moved per job: fetch 2·K tiles + write back one (f32).
    pub fn bytes_moved(&self) -> u64 {
        let tile_bytes = (self.grid.ts * self.grid.ts * 4) as u64;
        (2 * self.k_tiles() as u64 + 1) * tile_bytes
    }
}

/// A dispatchable job: metadata + shared operand buffers.
#[derive(Debug, Clone)]
pub struct Job {
    pub desc: JobDesc,
    /// A operand (weights matrix, M×N row-major) shared across the layer.
    pub a: Arc<Vec<f32>>,
    /// B operand (im2col matrix, N×P row-major) shared across the layer.
    pub b: Arc<Vec<f32>>,
}

/// Result of executing a job: the computed output tile.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub desc: JobDesc,
    /// (TS,TS) row-major output tile.
    pub tile: Vec<f32>,
}

impl Job {
    /// Pack this job's operand tiles into contiguous (K,TS,TS) buffers —
    /// the memory-subsystem fetch a PE performs (steps ①–② of Listing 3).
    pub fn pack_tiles(&self) -> (Vec<f32>, Vec<f32>) {
        (
            self.desc.grid.extract_a_tiles(&self.a, self.desc.t1),
            self.desc.grid.extract_b_tiles(&self.b, self.desc.t2),
        )
    }

    /// Execute on the native (NEON-path) kernel.
    pub fn execute_native(&self) -> JobResult {
        let (at, bt) = self.pack_tiles();
        let tile = job_mm_native(&at, &bt, self.desc.k_tiles(), self.desc.grid.ts);
        JobResult {
            desc: self.desc,
            tile,
        }
    }
}

/// Generate all jobs of one GEMM (one CONV layer instance of one frame).
/// `next_job_id` provides globally-unique ids across layers/frames.
pub fn jobs_for_gemm(
    layer_id: usize,
    frame_id: u64,
    grid: TileGrid,
    a: Arc<Vec<f32>>,
    b: Arc<Vec<f32>>,
    next_job_id: &mut u64,
) -> Vec<Job> {
    assert_eq!(a.len(), grid.m * grid.n, "A operand size mismatch");
    assert_eq!(b.len(), grid.n * grid.p, "B operand size mismatch");
    let mut jobs = Vec::with_capacity(grid.num_jobs());
    for (t1, t2) in grid.tiles() {
        let desc = JobDesc {
            job_id: *next_job_id,
            layer_id,
            frame_id,
            t1,
            t2,
            grid,
        };
        *next_job_id += 1;
        jobs.push(Job {
            desc,
            a: Arc::clone(&a),
            b: Arc::clone(&b),
        });
    }
    jobs
}

/// Assemble job results back into the dense C matrix (M×P).
pub fn gather_results(grid: TileGrid, results: &[JobResult]) -> Vec<f32> {
    assert_eq!(results.len(), grid.num_jobs(), "missing job results");
    let mut c = vec![0.0f32; grid.m * grid.p];
    for r in results {
        grid.scatter_c(&mut c, r.desc.t1, r.desc.t2, &r.tile);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::gemm::gemm_naive;
    use crate::tensor::Tensor;
    use crate::util::rng::XorShift64Star;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        XorShift64Star::new(seed).fill_f32(n, 2.0)
    }

    #[test]
    fn jobs_cover_grid_exactly_once() {
        let grid = TileGrid::new(70, 40, 90, 32);
        let a = Arc::new(rand_vec(70 * 40, 1));
        let b = Arc::new(rand_vec(40 * 90, 2));
        let mut id = 0;
        let jobs = jobs_for_gemm(3, 7, grid, a, b, &mut id);
        assert_eq!(jobs.len(), grid.num_jobs());
        assert_eq!(id, jobs.len() as u64);
        let mut seen = std::collections::HashSet::new();
        for j in &jobs {
            assert!(seen.insert((j.desc.t1, j.desc.t2)), "duplicate tile");
            assert_eq!(j.desc.layer_id, 3);
            assert_eq!(j.desc.frame_id, 7);
            assert!(j.desc.t1 < grid.rows() && j.desc.t2 < grid.cols());
        }
    }

    #[test]
    fn execute_and_gather_matches_gemm() {
        let grid = TileGrid::new(50, 70, 45, 32);
        let av = rand_vec(50 * 70, 3);
        let bv = rand_vec(70 * 45, 4);
        let a = Arc::new(av.clone());
        let b = Arc::new(bv.clone());
        let mut id = 0;
        let jobs = jobs_for_gemm(0, 0, grid, a, b, &mut id);
        let results: Vec<JobResult> = jobs.iter().map(|j| j.execute_native()).collect();
        let c = gather_results(grid, &results);
        let want = gemm_naive(
            &Tensor::from_vec(&[50, 70], av),
            &Tensor::from_vec(&[70, 45], bv),
        );
        let got = Tensor::from_vec(&[50, 45], c);
        assert!(want.allclose(&got, 1e-4, 1e-4), "{}", want.max_abs_diff(&got));
    }

    #[test]
    fn flops_and_bytes_accounting() {
        let grid = TileGrid::new(32, 96, 32, 32);
        let desc = JobDesc {
            job_id: 0,
            layer_id: 0,
            frame_id: 0,
            t1: 0,
            t2: 0,
            grid,
        };
        assert_eq!(desc.k_tiles(), 3);
        assert_eq!(desc.flops(), 2 * 32 * 32 * 32 * 3);
        assert_eq!(desc.bytes_moved(), (2 * 3 + 1) * 32 * 32 * 4);
    }

    #[test]
    #[should_panic(expected = "A operand size mismatch")]
    fn operand_size_checked() {
        let grid = TileGrid::new(4, 4, 4, 4);
        let mut id = 0;
        jobs_for_gemm(0, 0, grid, Arc::new(vec![0.0; 3]), Arc::new(vec![0.0; 16]), &mut id);
    }

    #[test]
    #[should_panic(expected = "missing job results")]
    fn gather_requires_all_results() {
        let grid = TileGrid::new(64, 32, 64, 32);
        gather_results(grid, &[]);
    }
}
