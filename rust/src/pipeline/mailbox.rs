//! Bounded blocking FIFO — the ReconOS-style *mailbox* connecting layer
//! threads in producer-consumer fashion.

use crate::util::sync::{lock_clean, wait_clean, Condvar, Mutex};
use std::collections::VecDeque;

struct Inner<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC mailbox.  `send` blocks when full (backpressure between
/// pipeline stages), `recv` blocks when empty; closing drains.
pub struct Mailbox<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> Mailbox<T> {
    pub fn new(capacity: usize) -> Self {
        Mailbox {
            inner: Mutex::new(Inner {
                buf: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Blocking send.  Returns false (message dropped) if closed.
    ///
    /// Wake-ups use `notify_all`: with multiple producers/consumers parked
    /// on the same condvar, `notify_one` can hand the token to a thread
    /// whose predicate is already stale (e.g. a second consumer that loses
    /// the race for the new item), and the intended waiter sleeps forever —
    /// the classic MPMC lost-wakeup.  Spurious wake-ups are cheap; a hung
    /// pipeline stage is not.
    pub fn send(&self, item: T) -> bool {
        let mut g = lock_clean(&self.inner);
        loop {
            if g.closed {
                return false;
            }
            if g.buf.len() < self.capacity {
                g.buf.push_back(item);
                drop(g);
                self.not_empty.notify_all();
                return true;
            }
            g = wait_clean(&self.not_full, g);
        }
    }

    /// Non-blocking send: `Err(item)` back to the caller when full or
    /// closed (the serving batcher hands batches to busy pipelines
    /// through this path instead of stalling on one of them).
    pub fn try_send(&self, item: T) -> Result<(), T> {
        let mut g = lock_clean(&self.inner);
        if g.closed || g.buf.len() >= self.capacity {
            return Err(item);
        }
        g.buf.push_back(item);
        drop(g);
        self.not_empty.notify_all();
        Ok(())
    }

    /// Blocking receive; None once closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut g = lock_clean(&self.inner);
        loop {
            if let Some(item) = g.buf.pop_front() {
                drop(g);
                self.not_full.notify_all();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = wait_clean(&self.not_empty, g);
        }
    }

    pub fn try_recv(&self) -> Option<T> {
        let mut g = lock_clean(&self.inner);
        let item = g.buf.pop_front();
        if item.is_some() {
            self.not_full.notify_all();
        }
        item
    }

    pub fn len(&self) -> usize {
        lock_clean(&self.inner).buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close for writers and release every parked thread.  Both condvars
    /// get a broadcast: consumers parked on `not_empty` must wake to see
    /// the drain-then-None contract, and producers parked on `not_full`
    /// must wake to return `false` — waking only one side (or one waiter)
    /// strands the rest forever.  `tests/loom_sync.rs` explores exactly
    /// this path and fails if either broadcast is weakened.
    pub fn close(&self) {
        lock_clean(&self.inner).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

// Thread/timing tests run on real OS scheduling; the loom build checks
// this module through `tests/loom_sync.rs` instead.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_per_producer() {
        let mb = Mailbox::new(4);
        for i in 0..4 {
            assert!(mb.send(i));
        }
        mb.close();
        let mut got = Vec::new();
        while let Some(v) = mb.recv() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn bounded_send_blocks_until_recv() {
        let mb = Arc::new(Mailbox::new(1));
        mb.send(1);
        let mb2 = Arc::clone(&mb);
        let t = thread::spawn(move || {
            // This send must block until the main thread receives.
            assert!(mb2.send(2));
        });
        thread::sleep(Duration::from_millis(10));
        assert_eq!(mb.len(), 1, "second send should be blocked");
        assert_eq!(mb.recv(), Some(1));
        t.join().unwrap();
        assert_eq!(mb.recv(), Some(2));
    }

    #[test]
    fn close_unblocks_and_drains() {
        let mb: Arc<Mailbox<u32>> = Arc::new(Mailbox::new(2));
        mb.send(9);
        let mb2 = Arc::clone(&mb);
        let t = thread::spawn(move || mb2.recv());
        thread::sleep(Duration::from_millis(5));
        mb.close();
        assert_eq!(t.join().unwrap(), Some(9));
        assert_eq!(mb.recv(), None);
        assert!(!mb.send(1), "send after close fails");
    }

    #[test]
    fn pipeline_of_three_stages() {
        // frame stream through 2 mailboxes with a transform per stage
        let a: Arc<Mailbox<u32>> = Arc::new(Mailbox::new(1));
        let b: Arc<Mailbox<u32>> = Arc::new(Mailbox::new(1));
        let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
        let stage = thread::spawn(move || {
            while let Some(v) = a2.recv() {
                b2.send(v * 10);
            }
            b2.close();
        });
        let a3 = Arc::clone(&a);
        let producer = thread::spawn(move || {
            for i in 0..20 {
                a3.send(i);
            }
            a3.close();
        });
        let mut got = Vec::new();
        while let Some(v) = b.recv() {
            got.push(v);
        }
        producer.join().unwrap();
        stage.join().unwrap();
        assert_eq!(got, (0..20).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn try_recv_nonblocking() {
        let mb: Mailbox<u32> = Mailbox::new(2);
        assert_eq!(mb.try_recv(), None);
        mb.send(5);
        assert_eq!(mb.try_recv(), Some(5));
    }

    #[test]
    fn try_send_rejects_when_full_or_closed() {
        let mb: Mailbox<u32> = Mailbox::new(1);
        assert!(mb.try_send(1).is_ok());
        assert_eq!(mb.try_send(2), Err(2));
        assert_eq!(mb.recv(), Some(1));
        assert!(mb.try_send(3).is_ok());
        mb.close();
        assert_eq!(mb.try_send(4), Err(4));
        assert_eq!(mb.recv(), Some(3));
        assert_eq!(mb.recv(), None);
    }
}
