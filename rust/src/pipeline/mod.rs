//! HW/SW multi-threaded pipeline plumbing (paper §3: "the communication
//! between layers is performed through a mailbox (a synchronized
//! first-in-first-out buffer) accessible by the threads").

pub mod mailbox;

pub use mailbox::Mailbox;
