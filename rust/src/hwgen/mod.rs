//! Hardware architecture generator (paper §3.3 / Fig 8).
//!
//! Takes a `.hw_config` ([`crate::config::HwConfig`]) and produces what the
//! paper's generator produces — minus the proprietary Vivado invocation,
//! which is replaced by a resource/timing *model* (the substitution is
//! documented in DESIGN.md §Hardware-Adaptation):
//!
//! * the **HLS C template** of each PE type (paper Listing 3) with the
//!   pragma set implied by its configuration ([`hls_template`]);
//! * the **RTL wiring manifest**: PEs ↔ control FIFOs ↔ delegate threads,
//!   MMU/arbiter/controller instances of the memory subsystem (Fig 5);
//! * the **resource report**: XC7Z020 LUT/FF/DSP/BRAM estimates per
//!   instance and in total, rejecting configurations that do not fit
//!   ([`resource`]);
//! * a **bitstream manifest** standing in for the `.bit` (content hash of
//!   everything above, so "reconfiguration needed?" is decidable).

pub mod generator;
pub mod hls_template;
pub mod resource;

pub use generator::{generate, GeneratedDesign};
pub use resource::{ResourceBudget, ResourceEstimate, ResourceReport};
