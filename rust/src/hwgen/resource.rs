//! XC7Z020 resource model: LUT/FF/DSP/BRAM estimates for generated
//! architectures, standing in for the Vivado synthesis report.
//!
//! Cost constants follow well-known Zynq-7000 synthesis results for f32
//! datapaths: a single-precision MAC (mul+add, full DSP mapping) costs
//! ≈5 DSP48E1s plus glue LUT/FF; a BRAM36 holds 1024 f32 words (one 32×32
//! tile); array partitioning into `p` banks multiplies BRAM count by the
//! bank granularity.

use std::fmt::Write as _;

use crate::config::{HwConfig, PeTypeCfg};

use super::hls_template;

/// Device budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceBudget {
    pub lut: u64,
    pub ff: u64,
    pub dsp: u64,
    pub bram36: u64,
}

impl ResourceBudget {
    /// Xilinx Zynq XC7Z020 (Artix-7 fabric).
    pub fn xc7z020() -> ResourceBudget {
        ResourceBudget {
            lut: 53_200,
            ff: 106_400,
            dsp: 220,
            bram36: 140,
        }
    }
}

/// Estimated usage of one component or the whole design.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResourceEstimate {
    pub lut: u64,
    pub ff: u64,
    pub dsp: u64,
    pub bram36: u64,
}

impl ResourceEstimate {
    pub fn add(&mut self, other: &ResourceEstimate) {
        self.lut += other.lut;
        self.ff += other.ff;
        self.dsp += other.dsp;
        self.bram36 += other.bram36;
    }

    pub fn scaled(&self, n: u64) -> ResourceEstimate {
        ResourceEstimate {
            lut: self.lut * n,
            ff: self.ff * n,
            dsp: self.dsp * n,
            bram36: self.bram36 * n,
        }
    }

    pub fn fits(&self, budget: &ResourceBudget) -> bool {
        self.lut <= budget.lut
            && self.ff <= budget.ff
            && self.dsp <= budget.dsp
            && self.bram36 <= budget.bram36
    }
}

/// Per-f32-MAC datapath cost on 7-series.
const MAC_DSP: u64 = 5;
const MAC_LUT: u64 = 750;
const MAC_FF: u64 = 1100;
/// PE control FSM + FIFO interfaces.
const PE_CTRL_LUT: u64 = 1600;
const PE_CTRL_FF: u64 = 2100;
/// Memory subsystem blocks (from ReconOS-class RTL).
const MMU_LUT: u64 = 900;
const MMU_FF: u64 = 1100;
const MMU_BRAM: u64 = 1; // TLB + walk buffers
const MEMCTRL_LUT: u64 = 1400;
const MEMCTRL_FF: u64 = 1800;
const ARBITER_LUT: u64 = 350;
const PROC_LUT: u64 = 800;
const PROC_FF: u64 = 900;

/// Estimate one PE instance from its pragma configuration.
pub fn estimate_pe(pt: &PeTypeCfg, tile_size: usize) -> ResourceEstimate {
    // Effective parallel MAC units ≈ the MAC/cycle the pragmas open up.
    let perf = crate::accel::PerfModel::fpga_pe(pt, tile_size, 100.0);
    let macs = perf.macs_per_cycle.ceil().max(1.0) as u64;
    // Tile buffers: a, b, c + double buffers for a and b = 5 tiles, each
    // TS²×4 B (one BRAM36 per 4 KiB).  Partition banks below ~1 KiB map to
    // BRAM18 halves / LUTRAM, so banking costs ≈1 BRAM36 per 4 banks, not
    // one per bank (this is how the paper fit 8 PEs on a ZC702).
    let tile_words = (tile_size * tile_size) as u64;
    let brams_per_array = (tile_words * 4).div_ceil(4096).max(1);
    let banks = pt.array_partition.max(1) as u64;
    let bram = 5 * brams_per_array + banks.div_ceil(4);
    ResourceEstimate {
        lut: PE_CTRL_LUT + macs * MAC_LUT,
        ff: PE_CTRL_FF + macs * MAC_FF,
        dsp: macs * MAC_DSP,
        bram36: bram,
    }
}

/// Memory subsystem estimate.
pub fn estimate_memsub(mmus: u64) -> ResourceEstimate {
    ResourceEstimate {
        lut: mmus * (MMU_LUT + MEMCTRL_LUT + ARBITER_LUT) + PROC_LUT + ARBITER_LUT,
        ff: mmus * (MMU_FF + MEMCTRL_FF) + PROC_FF,
        dsp: 0,
        bram36: mmus * MMU_BRAM,
    }
}

/// Full synthesis-style report.
#[derive(Debug, Clone)]
pub struct ResourceReport {
    pub budget: ResourceBudget,
    pub per_pe_type: Vec<(String, ResourceEstimate, usize)>,
    pub memsub: ResourceEstimate,
    pub total: ResourceEstimate,
}

impl ResourceReport {
    pub fn fits(&self) -> bool {
        self.total.fits(&self.budget)
    }

    /// Render like a Vivado utilization table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "Synergy synthesis estimate (device budget: {} LUT / {} FF / {} DSP / {} BRAM36)",
            self.budget.lut, self.budget.ff, self.budget.dsp, self.budget.bram36);
        let _ = writeln!(out, "{:-<78}", "");
        let _ = writeln!(out, "{:<24} {:>6} {:>8} {:>8} {:>6} {:>7}", "instance", "count", "LUT", "FF", "DSP", "BRAM36");
        for (name, est, count) in &self.per_pe_type {
            let _ = writeln!(out, "{:<24} {:>6} {:>8} {:>8} {:>6} {:>7}",
                name, count, est.lut, est.ff, est.dsp, est.bram36);
        }
        let _ = writeln!(out, "{:<24} {:>6} {:>8} {:>8} {:>6} {:>7}",
            "memory subsystem", 1, self.memsub.lut, self.memsub.ff, self.memsub.dsp, self.memsub.bram36);
        let _ = writeln!(out, "{:-<78}", "");
        let _ = writeln!(out, "{:<24} {:>6} {:>8} {:>8} {:>6} {:>7}",
            "TOTAL", "", self.total.lut, self.total.ff, self.total.dsp, self.total.bram36);
        let pct = |used: u64, avail: u64| 100.0 * used as f64 / avail as f64;
        let _ = writeln!(out, "{:<24} {:>6} {:>7.1}% {:>7.1}% {:>5.1}% {:>6.1}%",
            "utilization", "",
            pct(self.total.lut, self.budget.lut),
            pct(self.total.ff, self.budget.ff),
            pct(self.total.dsp, self.budget.dsp),
            pct(self.total.bram36, self.budget.bram36));
        let _ = writeln!(out, "fit: {}", if self.fits() { "YES" } else { "NO — over budget" });
        out
    }
}

/// Estimate a whole hardware configuration.
pub fn estimate(hw: &HwConfig) -> ResourceReport {
    let budget = ResourceBudget::xc7z020();
    let mut per_pe_type = Vec::new();
    let mut total = ResourceEstimate::default();
    for pt in &hw.pe_types {
        let count: usize = hw
            .clusters
            .iter()
            .flat_map(|c| c.pes.iter())
            .filter(|(name, _)| name == &pt.name)
            .map(|(_, n)| *n)
            .sum();
        if count == 0 {
            continue;
        }
        let est = estimate_pe(pt, hw.tile_size);
        total.add(&est.scaled(count as u64));
        per_pe_type.push((
            format!("{} ({})", pt.name, hls_template::c_ident(&pt.name)),
            est,
            count,
        ));
    }
    let memsub = estimate_memsub(hw.memsub.mmus as u64);
    total.add(&memsub);
    ResourceReport {
        budget,
        per_pe_type,
        memsub,
        total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_architecture_fits_zc702() {
        let hw = HwConfig::default_zc702();
        let report = estimate(&hw);
        assert!(report.fits(), "\n{}", report.render());
        // It should also *use* a meaningful fraction of the device.
        assert!(report.total.dsp >= 40, "{}", report.total.dsp);
        assert!(report.total.bram36 >= 50, "{}", report.total.bram36);
    }

    #[test]
    fn oversized_architecture_rejected() {
        let mut hw = HwConfig::default_zc702();
        hw.clusters[1].pes[0].1 = 60; // 60 F-PEs cannot fit
        hw.memsub.mmus = 30;
        let report = estimate(&hw);
        assert!(!report.fits());
        assert!(report.render().contains("NO — over budget"));
    }

    #[test]
    fn fast_pe_costs_more_dsp_than_slow() {
        let hw = HwConfig::default_zc702();
        let f = estimate_pe(hw.pe_type("F-PE").unwrap(), 32);
        let s = estimate_pe(hw.pe_type("S-PE").unwrap(), 32);
        assert!(f.dsp >= s.dsp);
        assert!(f.lut > 0 && s.lut > 0);
    }

    #[test]
    fn report_renders_table() {
        let hw = HwConfig::default_zc702();
        let r = estimate(&hw).render();
        assert!(r.contains("TOTAL"));
        assert!(r.contains("utilization"));
        assert!(r.contains("F-PE"));
        assert!(r.contains("memory subsystem"));
    }

    #[test]
    fn estimate_arith() {
        let a = ResourceEstimate {
            lut: 1,
            ff: 2,
            dsp: 3,
            bram36: 4,
        };
        let b = a.scaled(3);
        assert_eq!(b.dsp, 9);
        let mut c = a;
        c.add(&b);
        assert_eq!(c.lut, 4);
        assert!(c.fits(&ResourceBudget::xc7z020()));
    }
}
