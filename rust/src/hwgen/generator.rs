//! The end-to-end generation flow (paper Fig 8): `.hw_config` in, design
//! directory out — PE HLS sources, wiring manifest, synthesis-style
//! resource report, and a bitstream manifest.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::accel::build_clusters;
use crate::config::HwConfig;
use crate::util::json::{self, Json};
use crate::util::rng::fnv1a64;

use super::hls_template;
use super::resource::{self, ResourceReport};

/// Everything the generator produced.
#[derive(Debug)]
pub struct GeneratedDesign {
    pub dir: PathBuf,
    pub pe_sources: Vec<(String, PathBuf)>,
    pub wiring_manifest: PathBuf,
    pub report: ResourceReport,
    pub bitstream_manifest: PathBuf,
    /// Content hash — two configs with the same hash need no
    /// reconfiguration (the paper's "bitstream remains unchanged" point).
    pub bitstream_hash: u64,
}

/// Run the generator for `hw`, writing into `out_dir`.
pub fn generate(hw: &HwConfig, out_dir: &Path) -> Result<GeneratedDesign> {
    hw.validate()?;
    let report = resource::estimate(hw);
    if !report.fits() {
        bail!(
            "architecture does not fit {}:\n{}",
            hw.device,
            report.render()
        );
    }
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;

    // 1. PE HLS sources (only types actually instantiated).
    let mut pe_sources = Vec::new();
    let mut hash_acc = String::new();
    for pt in &hw.pe_types {
        let instantiated = hw
            .clusters
            .iter()
            .flat_map(|c| c.pes.iter())
            .any(|(name, n)| name == &pt.name && *n > 0);
        if !instantiated {
            continue;
        }
        let src = hls_template::emit_pe_source(pt, hw.tile_size);
        let fname = format!("{}.c", hls_template::c_ident(&pt.name));
        let path = out_dir.join(&fname);
        std::fs::write(&path, &src)?;
        hash_acc.push_str(&src);
        pe_sources.push((pt.name.clone(), path));
    }

    // 2. Wiring manifest: the Fig 5 architecture as JSON.
    let clusters = build_clusters(hw);
    let mut cluster_json = Vec::new();
    for c in &clusters {
        let members: Vec<Json> = c
            .members
            .iter()
            .map(|m| {
                json::obj(vec![
                    ("id", json::num(m.id as f64)),
                    ("name", json::s(&m.name)),
                    (
                        "kind",
                        json::s(match &m.class {
                            crate::accel::AccelClass::FpgaPe { .. } => "fpga_pe",
                            crate::accel::AccelClass::Neon => "neon",
                            crate::accel::AccelClass::BigNeon => "big_neon",
                            // No hardware to generate: the member is a
                            // transport endpoint; the wiring manifest
                            // still records it for the deployment map.
                            crate::accel::AccelClass::Remote { .. } => "remote_shard",
                        }),
                    ),
                    (
                        "mmu_channel",
                        m.mmu.map(|v| json::num(v as f64)).unwrap_or(Json::Null),
                    ),
                    ("control_fifos", json::arr(vec![
                        json::s(&format!("if_sw2hw_{}", m.id)),
                        json::s(&format!("if_hw2sw_{}", m.id)),
                    ])),
                    ("memory_fifos", json::arr(vec![
                        json::s(&format!("if_mem2hw_{}", m.id)),
                        json::s(&format!("if_hw2mem_{}", m.id)),
                    ])),
                ])
            })
            .collect();
        cluster_json.push(json::obj(vec![
            ("name", json::s(&c.name)),
            ("members", json::arr(members)),
        ]));
    }
    let wiring = json::obj(vec![
        ("device", json::s(&hw.device)),
        ("fpga_mhz", json::num(hw.fpga_mhz)),
        ("tile_size", json::num(hw.tile_size as f64)),
        ("clusters", json::arr(cluster_json)),
        (
            "memory_subsystem",
            json::obj(vec![
                ("mmus", json::num(hw.memsub.mmus as f64)),
                ("pes_per_mmu", json::num(hw.memsub.pes_per_mmu as f64)),
                ("tlb_entries", json::num(hw.memsub.tlb_entries as f64)),
                ("proc_units", json::num(1.0)),
                ("proc_arbiter", Json::Bool(true)),
            ]),
        ),
    ]);
    let wiring_path = out_dir.join("wiring.json");
    std::fs::write(&wiring_path, wiring.to_string())?;
    hash_acc.push_str(&wiring.to_string());

    // 3. Synthesis-style resource report.
    std::fs::write(out_dir.join("synthesis_report.txt"), report.render())?;

    // 4. Bitstream manifest (content hash stands in for the .bit).
    let bitstream_hash = fnv1a64(&hash_acc);
    let bit = json::obj(vec![
        ("device", json::s(&hw.device)),
        ("hash", json::s(&format!("{bitstream_hash:#018x}"))),
        ("fits", Json::Bool(true)),
    ]);
    let bit_path = out_dir.join("bitstream.json");
    std::fs::write(&bit_path, bit.to_string())?;

    Ok(GeneratedDesign {
        dir: out_dir.to_path_buf(),
        pe_sources,
        wiring_manifest: wiring_path,
        report,
        bitstream_manifest: bit_path,
        bitstream_hash,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "synergy_hwgen_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn default_config_generates_complete_design() {
        let hw = HwConfig::default_zc702();
        let dir = tmpdir("default");
        let design = generate(&hw, &dir).unwrap();
        // Two PE types, both instantiated.
        assert_eq!(design.pe_sources.len(), 2);
        for (_, path) in &design.pe_sources {
            assert!(path.exists());
        }
        assert!(design.wiring_manifest.exists());
        assert!(design.bitstream_manifest.exists());
        assert!(dir.join("synthesis_report.txt").exists());

        // Wiring parses back and matches the architecture.
        let wiring = json::parse(&std::fs::read_to_string(&design.wiring_manifest).unwrap()).unwrap();
        let clusters = wiring.get("clusters").unwrap().as_arr().unwrap();
        assert_eq!(clusters.len(), 2);
        let c1_members = clusters[1].get("members").unwrap().as_arr().unwrap();
        assert_eq!(c1_members.len(), 6); // 6 F-PE
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bitstream_hash_stable_across_models_changes_with_hw() {
        let hw = HwConfig::default_zc702();
        let d1 = tmpdir("h1");
        let d2 = tmpdir("h2");
        let g1 = generate(&hw, &d1).unwrap();
        let g2 = generate(&hw, &d2).unwrap();
        // Same architecture → same bitstream (network-independent!).
        assert_eq!(g1.bitstream_hash, g2.bitstream_hash);
        // Different architecture → different bitstream.
        let hw2 = HwConfig::two_clusters((2, 2, 2), (0, 0, 4));
        let d3 = tmpdir("h3");
        let g3 = generate(&hw2, &d3).unwrap();
        assert_ne!(g1.bitstream_hash, g3.bitstream_hash);
        for d in [d1, d2, d3] {
            std::fs::remove_dir_all(&d).unwrap();
        }
    }

    #[test]
    fn oversized_config_refused() {
        let mut hw = HwConfig::default_zc702();
        hw.clusters[1].pes[0].1 = 98; // 100 PEs total
        hw.memsub.mmus = 50;
        let dir = tmpdir("big");
        let err = generate(&hw, &dir).unwrap_err().to_string();
        assert!(err.contains("does not fit"), "{err}");
    }
}
