//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime: which HLO files exist, their tile size / K values,
//! and the canonical parameter order of every model artifact.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// One AOT job kernel (per-K Pallas PE kernel).
#[derive(Debug, Clone, PartialEq)]
pub struct JobKernelMeta {
    pub k: usize,
    pub path: String,
}

/// One model parameter in canonical order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamMeta {
    pub layer: usize,
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamMeta {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One AOT model artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    pub name: String,
    pub path: String,
    pub input_shape: Vec<usize>,
    pub mops: f64,
    pub params: Vec<ParamMeta>,
}

/// Parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub tile_size: usize,
    pub job_kernels: Vec<JobKernelMeta>,
    pub models: Vec<ModelMeta>,
}

impl Manifest {
    pub fn load(artifacts: &Path) -> Result<Manifest> {
        let path = artifacts.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} (run `make artifacts` first)",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let tile_size = root
            .get("tile_size")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing tile_size"))?;

        let mut job_kernels = Vec::new();
        for jk in root
            .get("job_kernels")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing job_kernels"))?
        {
            job_kernels.push(JobKernelMeta {
                k: jk
                    .get("k")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("job kernel missing k"))?,
                path: jk
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("job kernel missing path"))?
                    .to_string(),
            });
        }

        let mut models = Vec::new();
        for m in root
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let mut params = Vec::new();
            for p in m
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("model missing params"))?
            {
                params.push(ParamMeta {
                    layer: p
                        .get("layer")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("param missing layer"))?,
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("param missing shape"))?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                });
            }
            models.push(ModelMeta {
                name: m
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("model missing name"))?
                    .to_string(),
                path: m
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("model missing path"))?
                    .to_string(),
                input_shape: m
                    .get("input_shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("model missing input_shape"))?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
                mops: m.get("mops").and_then(Json::as_f64).unwrap_or(0.0),
                params,
            });
        }

        Ok(Manifest {
            tile_size,
            job_kernels,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Option<&ModelMeta> {
        self.models.iter().find(|m| m.name == name)
    }

    pub fn k_values(&self) -> Vec<usize> {
        self.job_kernels.iter().map(|jk| jk.k).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "tile_size": 32,
      "job_kernels": [{"k": 1, "path": "job_mm_ts32_k1.hlo.txt", "tile_size": 32}],
      "models": [{
        "name": "mini", "path": "model_mini.hlo.txt",
        "input_shape": [1, 8, 8], "mops": 0.5,
        "params": [
          {"layer": 0, "name": "weights", "shape": [4, 1, 3, 3]},
          {"layer": 0, "name": "bias", "shape": [4]}
        ],
        "conv_gemms": []
      }]
    }"#;

    #[test]
    fn parse_sample() {
        let man = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(man.tile_size, 32);
        assert_eq!(man.k_values(), vec![1]);
        let model = man.model("mini").unwrap();
        assert_eq!(model.input_shape, vec![1, 8, 8]);
        assert_eq!(model.params.len(), 2);
        assert_eq!(model.params[0].len(), 36);
        assert!(man.model("nope").is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse(r#"{"tile_size": 32}"#).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.tile_size, 32);
        assert_eq!(man.models.len(), 7);
        assert!(man.job_kernels.len() >= 9);
        // All referenced artifact files exist.
        for jk in &man.job_kernels {
            assert!(dir.join(&jk.path).exists(), "{}", jk.path);
        }
        for m in &man.models {
            assert!(dir.join(&m.path).exists(), "{}", m.path);
        }
    }
}
