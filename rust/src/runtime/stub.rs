//! API-compatible stand-ins for the PJRT engines, compiled when the `pjrt`
//! feature is off.  They keep every call-site (delegates, tests, examples)
//! building without the XLA toolchain; any attempt to actually construct an
//! engine reports a clean error so callers fall back to the native GEMM.

use std::path::Path;

use anyhow::{bail, Result};

use super::manifest::{Manifest, ModelMeta};

const NO_PJRT: &str =
    "PJRT support not compiled in (rebuild with `--features pjrt`); use the native backend";

/// Stand-in for the per-thread PE engine.  `load` always fails after the
/// manifest check, so instances never exist in non-`pjrt` builds.
pub struct PeEngine {
    _private: (),
}

impl PeEngine {
    /// Checks the artifacts directory (same diagnostics as the real engine
    /// for a missing manifest), then reports that PJRT is unavailable.
    pub fn load(artifacts: &Path, _ks: Option<&[usize]>) -> Result<PeEngine> {
        let _ = Manifest::load(artifacts)?;
        bail!(NO_PJRT)
    }

    pub fn tile_size(&self) -> usize {
        0
    }

    pub fn available_ks(&self) -> Vec<usize> {
        Vec::new()
    }

    pub fn kernel_k_for(&self, _k: usize) -> Result<usize> {
        bail!(NO_PJRT)
    }

    pub fn execute_job(&self, _a: &[f32], _b: &[f32], _k: usize) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }
}

/// Stand-in for the full-model oracle.
pub struct ModelOracle {
    pub meta: ModelMeta,
}

impl ModelOracle {
    pub fn load(artifacts: &Path, _model: &str) -> Result<ModelOracle> {
        let _ = Manifest::load(artifacts)?;
        bail!(NO_PJRT)
    }

    pub fn run(&self, _x: &[f32], _params: &[&[f32]]) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }
}
