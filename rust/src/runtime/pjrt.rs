//! Real PJRT engines (compiled only with the `pjrt` feature): load the AOT
//! artifacts and execute them on the XLA PJRT CPU client.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::manifest::{Manifest, ModelMeta};

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

/// Per-thread PE engine: a PJRT CPU client plus the compiled job kernels
/// for the K values this PE will encounter.
pub struct PeEngine {
    client: xla::PjRtClient,
    kernels: HashMap<usize, xla::PjRtLoadedExecutable>,
    tile_size: usize,
}

impl PeEngine {
    /// Load and compile job kernels for the given K values (None = all in
    /// the manifest).
    pub fn load(artifacts: &Path, ks: Option<&[usize]>) -> Result<PeEngine> {
        let man = Manifest::load(artifacts)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut kernels = HashMap::new();
        for jk in &man.job_kernels {
            if let Some(filter) = ks {
                if !filter.contains(&jk.k) {
                    continue;
                }
            }
            kernels.insert(jk.k, compile(&client, &artifacts.join(&jk.path))?);
        }
        if kernels.is_empty() {
            anyhow::bail!("no job kernels loaded from {}", artifacts.display());
        }
        Ok(PeEngine {
            client,
            kernels,
            tile_size: man.tile_size,
        })
    }

    pub fn tile_size(&self) -> usize {
        self.tile_size
    }

    pub fn available_ks(&self) -> Vec<usize> {
        let mut ks: Vec<usize> = self.kernels.keys().copied().collect();
        ks.sort_unstable();
        ks
    }

    /// Smallest compiled kernel with K' ≥ k (operands are zero-padded up to
    /// K' — zero tiles contribute nothing, exactly the paper's border rule).
    pub fn kernel_k_for(&self, k: usize) -> Result<usize> {
        self.kernels
            .keys()
            .copied()
            .filter(|&kk| kk >= k)
            .min()
            .ok_or_else(|| anyhow!("no compiled job kernel covers k={k}"))
    }

    /// Execute one job on the PJRT path: packed (K,TS,TS) operand tiles →
    /// (TS,TS) output tile.
    pub fn execute_job(&self, a_tiles: &[f32], b_tiles: &[f32], k: usize) -> Result<Vec<f32>> {
        let ts = self.tile_size;
        debug_assert_eq!(a_tiles.len(), k * ts * ts);
        debug_assert_eq!(b_tiles.len(), k * ts * ts);
        let kk = self.kernel_k_for(k)?;
        let exe = &self.kernels[&kk];
        // Pad with zero tiles up to the kernel's K if needed.
        let (a_lit, b_lit) = if kk == k {
            (make_literal(a_tiles, kk, ts)?, make_literal(b_tiles, kk, ts)?)
        } else {
            let mut ap = a_tiles.to_vec();
            let mut bp = b_tiles.to_vec();
            ap.resize(kk * ts * ts, 0.0);
            bp.resize(kk * ts * ts, 0.0);
            (make_literal(&ap, kk, ts)?, make_literal(&bp, kk, ts)?)
        };
        let result = exe
            .execute::<xla::Literal>(&[a_lit, b_lit])
            .context("executing job kernel")?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching job result")?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let tile = lit.to_tuple1().context("unwrapping result tuple")?;
        let out = tile.to_vec::<f32>().context("reading result tile")?;
        debug_assert_eq!(out.len(), ts * ts);
        Ok(out)
    }

    /// Access the underlying client (e.g. to compile extra computations).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

fn make_literal(data: &[f32], k: usize, ts: usize) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(&[k as i64, ts as i64, ts as i64])?)
}

/// Full-model oracle: executes `model_{name}.hlo.txt` through PJRT.
pub struct ModelOracle {
    #[allow(dead_code)] // keeps the client alive for the executable
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub meta: ModelMeta,
}

impl ModelOracle {
    pub fn load(artifacts: &Path, model: &str) -> Result<ModelOracle> {
        let man = Manifest::load(artifacts)?;
        let meta = man
            .models
            .iter()
            .find(|m| m.name == model)
            .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?
            .clone();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let exe = compile(&client, &artifacts.join(&meta.path))?;
        Ok(ModelOracle { client, exe, meta })
    }

    /// Run the forward pass: input (C·H·W flat) + params in manifest order →
    /// class probabilities.
    pub fn run(&self, x: &[f32], params: &[&[f32]]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            params.len() == self.meta.params.len(),
            "expected {} params, got {}",
            self.meta.params.len(),
            params.len()
        );
        let mut lits = Vec::with_capacity(1 + params.len());
        let shape: Vec<i64> = self.meta.input_shape.iter().map(|&d| d as i64).collect();
        lits.push(xla::Literal::vec1(x).reshape(&shape)?);
        for (meta, data) in self.meta.params.iter().zip(params) {
            anyhow::ensure!(
                meta.len() == data.len(),
                "param {}/{} expects {} elems, got {}",
                meta.layer,
                meta.name,
                meta.len(),
                data.len()
            );
            let dims: Vec<i64> = meta.shape.iter().map(|&d| d as i64).collect();
            lits.push(xla::Literal::vec1(data).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?;
        let lit = result[0][0].to_literal_sync()?;
        let out = lit.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}
