//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt` produced
//! by `make artifacts`) and executes them on the XLA PJRT CPU client.
//!
//! Two artifact families exist (see `python/compile/aot.py`):
//!
//! * **job kernels** `job_mm_ts{TS}_k{K}.hlo.txt` — the Pallas PE kernel for
//!   one Synergy job: (A[K,TS,TS], B[K,TS,TS]) → (C[TS,TS],).  Executed by
//!   the FPGA-PE delegate threads on the inference hot path.
//! * **model oracles** `model_{name}.hlo.txt` — the full CNN forward with
//!   weights as parameters; used by integration tests to validate the whole
//!   Rust pipeline numerically.
//!
//! `xla::PjRtClient` is `Rc`-backed (not `Send`), so every delegate thread
//! owns a private [`PeEngine`] — which mirrors the hardware reality that
//! each PE is a separate physical instance of the kernel.
//!
//! The XLA dependency is optional: without the `pjrt` cargo feature this
//! module compiles API-compatible stubs (`stub.rs`) whose constructors fail
//! cleanly, and the delegates fall back to the native blocked GEMM.

pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
mod stub;

use std::path::PathBuf;

pub use manifest::{JobKernelMeta, Manifest, ModelMeta, ParamMeta};
#[cfg(feature = "pjrt")]
pub use pjrt::{ModelOracle, PeEngine};
#[cfg(not(feature = "pjrt"))]
pub use stub::{ModelOracle, PeEngine};

/// True when this build can execute the AOT artifacts through PJRT.
pub const PJRT_COMPILED: bool = cfg!(feature = "pjrt");

/// Locate the artifacts directory: `$SYNERGY_ARTIFACTS`, else `./artifacts`,
/// else `<crate root>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("SYNERGY_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.join("manifest.json").exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves() {
        let dir = default_artifacts_dir();
        // In-repo builds always have CARGO_MANIFEST_DIR/artifacts after
        // `make artifacts`; we only assert the path is non-empty here.
        assert!(!dir.as_os_str().is_empty());
    }

    #[test]
    fn engine_load_from_bogus_path_fails_cleanly() {
        let err = PeEngine::load(std::path::Path::new("/nonexistent/x"), None)
            .err()
            .expect("must fail")
            .to_string();
        assert!(err.contains("reading") || err.contains("manifest"), "{err}");
    }
}
