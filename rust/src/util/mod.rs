//! Small self-contained substrates the offline build cannot pull from
//! crates.io: deterministic PRNG, JSON, CLI parsing, statistics, a
//! micro-benchmark harness, and the concurrency-checking pair — the
//! [`sync`] facade every blocking primitive locks through and the
//! [`model`] bounded exhaustive scheduler behind the `--cfg loom` build.

pub mod argparse;
pub mod bench;
pub mod json;
pub mod model;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;
