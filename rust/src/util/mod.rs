//! Small self-contained substrates the offline build cannot pull from
//! crates.io: deterministic PRNG, JSON, CLI parsing, statistics, and a
//! micro-benchmark harness.

pub mod argparse;
pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
