//! Micro-benchmark harness (no criterion in the offline registry).
//!
//! Used by the `rust/benches/*.rs` binaries (`harness = false`): warmup,
//! timed iterations, outlier-robust statistics, and markdown table output
//! shared by every paper-figure bench.

use std::time::{Duration, Instant};

use super::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Throughput in ops/sec for `ops` work-items per iteration.
    pub fn throughput(&self, ops: f64) -> f64 {
        ops / (self.mean_ns / 1e9)
    }
}

/// Runs closures with warmup + timed iterations.
pub struct Bencher {
    pub warmup_iters: usize,
    pub iters: usize,
    pub min_duration: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            iters: 10,
            min_duration: Duration::from_millis(200),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            iters: 5,
            min_duration: Duration::from_millis(50),
        }
    }

    /// Benchmark `f`, auto-scaling inner repetitions so each timed sample
    /// lasts long enough to be meaningful.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Calibrate inner reps.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let reps = (self.min_duration.as_nanos() / self.iters as u128 / once.as_nanos())
            .clamp(1, 1_000_000) as usize;

        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            for _ in 0..reps {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / reps as f64);
        }
        let mean = stats::mean(&samples);
        let med = stats::percentile(&samples, 50.0);
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / samples.len().max(1) as f64;
        BenchResult {
            name: name.to_string(),
            iters: self.iters * reps,
            mean_ns: mean,
            stddev_ns: var.sqrt(),
            median_ns: med,
            min_ns: min,
        }
    }
}

/// Markdown table builder for bench / experiment output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let body: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |\n", body.join(" | "))
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep, &widths));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with sensible precision for table cells.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns * 2.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "fps"]);
        t.row(vec!["mnist".into(), "96.2".into()]);
        t.row(vec!["cifar_full".into(), "63.5".into()]);
        let s = t.render();
        assert!(s.contains("| model      | fps  |"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_precision() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(1234.6), "1235");
        assert_eq!(fmt(42.25), "42.2");
        assert_eq!(fmt(3.14159), "3.14");
    }
}
