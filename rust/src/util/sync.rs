//! Synchronization facade for the runtime's blocking primitives.
//!
//! Two jobs in one module:
//!
//! * **Poison tolerance.**  A delegate thread that panics mid-job poisons
//!   any `Mutex` it holds.  The shutdown and report paths must still be
//!   able to read counters and drain queues — a panicking worker must not
//!   cascade-poison the bank and wedge `DelegatePool::shutdown` (the pool
//!   already counts the failure via the join-side error path).  All lock
//!   state guarded by these mutexes is a plain value snapshot (queues,
//!   counter vectors): there is no partially-applied multi-step invariant
//!   a panic could tear, so recovering the inner value is sound.
//!   [`lock_clean`] / [`wait_clean`] / [`wait_timeout_clean`] encode that
//!   decision once; `synergy-lint` bans bare `.lock().unwrap()` in the
//!   delegate-reachable modules so the decision cannot silently erode.
//!
//! * **Model-checking switch.**  Built with `--cfg loom` (the loom CI
//!   job: `RUSTFLAGS="--cfg loom" cargo test --test loom_sync --release`),
//!   `Mutex`/`Condvar` rebind to the in-tree bounded exhaustive scheduler
//!   in [`crate::util::model`], so `Mailbox` and `QueueBank` run their
//!   real production code under every explored interleaving.  The offline
//!   build cannot pull the `loom` crate from crates.io; the model module
//!   implements the same exploration idea (CHESS-style iterative context
//!   bounding) against this facade instead.

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use crate::util::model::sync::{Condvar, Mutex, MutexGuard};

#[cfg(not(loom))]
use std::time::Duration;
#[cfg(loom)]
use std::time::Duration;

/// Lock, recovering the inner value if a previous holder panicked.
///
/// See the module docs for why recovery is sound here: every guarded
/// structure is snapshot-consistent at each lock release, so a poisoned
/// flag carries no information the caller needs.
#[cfg(not(loom))]
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Model-checked builds: the model mutex has no poisoning (a panicking
/// task aborts the whole execution), so this is a plain lock.
#[cfg(loom)]
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock()
}

/// Condvar wait with the same poison story as [`lock_clean`].
#[cfg(not(loom))]
pub fn wait_clean<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(loom)]
pub fn wait_clean<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g)
}

/// Timed condvar wait; returns the re-acquired guard and whether the wait
/// timed out.
#[cfg(not(loom))]
pub fn wait_timeout_clean<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    timeout: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(g, timeout) {
        Ok((g, res)) => (g, res.timed_out()),
        Err(poisoned) => {
            let (g, res) = poisoned.into_inner();
            (g, res.timed_out())
        }
    }
}

/// The model scheduler has no wall clock: a timed wait blocks until a
/// notification arrives (never "times out").  Exploration scenarios that
/// use timeout-popping APIs must therefore release their waiters via
/// `close()`/pushes (exactly the paths the loom suite checks) and pass
/// timeouts large enough that the real-time deadline checks around the
/// wait never fire during a model run.
#[cfg(loom)]
pub fn wait_timeout_clean<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    _timeout: Duration,
) -> (MutexGuard<'a, T>, bool) {
    (cv.wait(g), false)
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_clean_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_clean(&m), 7, "value recovered despite poison");
        *lock_clean(&m) = 8;
        assert_eq!(*lock_clean(&m), 8);
    }

    #[test]
    fn wait_timeout_clean_reports_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_clean(&m);
        let (_g, timed_out) = wait_timeout_clean(&cv, g, Duration::from_millis(1));
        assert!(timed_out);
    }
}
