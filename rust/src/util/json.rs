//! Minimal JSON parser + writer (no serde in the offline registry).
//!
//! Covers the full JSON grammar needed by `artifacts/manifest.json` and the
//! metrics emitters: objects, arrays, strings (with escapes), numbers,
//! booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Serialize to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Parse JSON text.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex digit")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err("bad escape".into()),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: re-decode from the source slice.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| "bad utf8".to_string())?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let text = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2.5));
        // serialize → reparse is identity
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"tile_size": 32, "job_kernels": [{"k": 1, "path": "a.txt"}]}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("tile_size").unwrap().as_usize(), Some(32));
        let jk = &v.get("job_kernels").unwrap().as_arr().unwrap()[0];
        assert_eq!(jk.get("path").unwrap().as_str(), Some("a.txt"));
    }

    #[test]
    fn errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} trailing").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("tab\there \"quoted\" \\ done\n".into());
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode() {
        let v = parse(r#""é café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("é café ☕"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("3.25e2").unwrap().as_f64(), Some(325.0));
        assert_eq!(parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
