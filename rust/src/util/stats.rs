//! Streaming and batch statistics used by the metrics collectors and the
//! bench harness.

use std::collections::VecDeque;

/// Welford online mean/variance accumulator.
#[derive(Debug, Default, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Rolling-window quantile estimator over the last `cap` samples.
///
/// The serving batcher feeds per-tier *deadline headroom* samples (ms of
/// budget left when a request dispatches) through one of these; the
/// adaptive batch-window policy reads a low quantile back to decide
/// whether batching delay is eating the tier's tail budget.  A bounded
/// window (not a decaying sketch) keeps the estimate deterministic for a
/// deterministic sample sequence — the virtual-time tests rely on that.
#[derive(Debug, Clone)]
pub struct RollingQuantile {
    cap: usize,
    buf: VecDeque<f64>,
}

impl RollingQuantile {
    /// `cap` is clamped to ≥ 1.
    pub fn new(cap: usize) -> RollingQuantile {
        let cap = cap.max(1);
        RollingQuantile {
            cap,
            buf: VecDeque::with_capacity(cap),
        }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(x);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Nearest-rank quantile over the current window; `None` when empty.
    pub fn quantile(&self, pct: f64) -> Option<f64> {
        if self.buf.is_empty() {
            return None;
        }
        let samples: Vec<f64> = self.buf.iter().copied().collect();
        Some(percentile(&samples, pct))
    }
}

/// Percentile of a sample set (nearest-rank on a sorted copy).
pub fn percentile(samples: &[f64], pct: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((pct / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        0.0
    } else {
        samples.iter().sum::<f64>() / samples.len() as f64
    }
}

/// Geometric mean (the paper averages speedups; geomean is the honest way).
pub fn geomean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    (samples.iter().map(|x| x.ln()).sum::<f64>() / samples.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0, 16.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn rolling_quantile_windows_out_old_samples() {
        let mut r = RollingQuantile::new(4);
        assert_eq!(r.quantile(50.0), None);
        for x in [10.0, 20.0, 30.0, 40.0] {
            r.push(x);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.quantile(0.0), Some(10.0));
        assert_eq!(r.quantile(100.0), Some(40.0));
        // Two more pushes evict 10 and 20: the low quantile moves up.
        r.push(50.0);
        r.push(60.0);
        assert_eq!(r.len(), 4);
        assert_eq!(r.quantile(0.0), Some(30.0));
        assert_eq!(r.quantile(100.0), Some(60.0));
    }

    #[test]
    fn rolling_quantile_cap_clamps_to_one() {
        let mut r = RollingQuantile::new(0);
        assert_eq!(r.cap(), 1);
        r.push(1.0);
        r.push(2.0);
        assert_eq!(r.len(), 1);
        assert_eq!(r.quantile(50.0), Some(2.0));
    }

    #[test]
    fn empty_welford() {
        let w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.stddev(), 0.0);
        assert_eq!(w.min(), 0.0);
    }
}
