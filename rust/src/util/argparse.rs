//! Tiny CLI argument parser (no clap in the offline registry).
//!
//! Supports `prog <subcommand> [--key value] [--flag] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]).  `flag_names` lists options that
    /// take no value; everything else starting with `--` consumes one.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| format!("option --{name} needs a value"))?;
                    out.options.insert(name.to_string(), val.clone());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{key} expects an integer: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key} expects a number: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = Args::parse(
            &raw(&["run", "--model", "mnist", "--verbose", "extra1", "extra2"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("model"), Some("mnist"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&raw(&["bench", "--frames=50"]), &[]).unwrap();
        assert_eq!(a.get_usize("frames", 0).unwrap(), 50);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&raw(&["run", "--model"]), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&raw(&["x"]), &[]).unwrap();
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 9).unwrap(), 9);
        assert_eq!(a.get_f64("f", 2.5).unwrap(), 2.5);
    }

    #[test]
    fn bad_numbers() {
        let a = Args::parse(&raw(&["x", "--n", "abc"]), &[]).unwrap();
        assert!(a.get_usize("n", 0).is_err());
        assert!(a.get_f64("n", 0.0).is_err());
    }
}
