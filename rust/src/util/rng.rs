//! xorshift64* PRNG with FNV-1a seed derivation.
//!
//! This is the **cross-language parameter contract**: `python/compile/prng.py`
//! implements the identical generator so the Rust coordinator and the AOT
//! model artifacts materialize bit-identical f32 weights.  The known-answer
//! vectors pinned in the tests here are also pinned in
//! `python/tests/test_aot.py::test_prng_known_vector`.

const XS_MULT: u64 = 0x2545_F491_4F6C_DD1D;
const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;
/// Substituted for an all-zeros seed (xorshift state must be non-zero).
const ZERO_SEED_FOLD: u64 = 0x9E37_79B9_7F4A_7C15;

/// FNV-1a 64-bit hash of a UTF-8 string (used for per-tensor seeds).
pub fn fnv1a64(s: &str) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// xorshift64* generator.
#[derive(Debug, Clone)]
pub struct XorShift64Star {
    state: u64,
}

impl XorShift64Star {
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { ZERO_SEED_FOLD } else { seed },
        }
    }

    /// Seed from a string via FNV-1a (the canonical per-tensor scheme).
    pub fn from_key(key: &str) -> Self {
        Self::new(fnv1a64(key))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(XS_MULT)
    }

    /// Uniform in [-0.5, 0.5); exact in f64 (24 mantissa bits used).
    pub fn next_unit(&mut self) -> f64 {
        (self.next_u64() >> 40) as f64 / (1u64 << 24) as f64 - 0.5
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        self.next_unit() + 0.5
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// f32 tensor fill matching `python/compile/prng.py::fill`:
    /// value = f32(next_unit() * scale), row-major.
    pub fn fill_f32(&mut self, n: usize, scale: f64) -> Vec<f32> {
        (0..n).map(|_| (self.next_unit() * scale) as f32).collect()
    }
}

/// Canonical per-tensor seed key (`model/layer/kind`), mirroring
/// `prng.tensor_seed` on the Python side.
pub fn tensor_key(model: &str, layer: usize, kind: &str) -> String {
    format!("{model}/{layer}/{kind}")
}

/// Deterministic tensor fill by key: `fill(model, layer, kind, n, scale)`.
pub fn fill_tensor(model: &str, layer: usize, kind: &str, n: usize, scale: f64) -> Vec<f32> {
    XorShift64Star::from_key(&tensor_key(model, layer, kind)).fill_f32(n, scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vector_matches_python() {
        // Pinned in python/tests/test_aot.py::test_prng_known_vector.
        let mut r = XorShift64Star::new(1);
        assert_eq!(r.next_u64(), 0x47E4_CE4B_896C_DD1D);
        assert_eq!(r.next_u64(), 0xABCF_A6A8_E079_651D);
    }

    #[test]
    fn zero_seed_folds() {
        let mut a = XorShift64Star::new(0);
        let mut b = XorShift64Star::new(ZERO_SEED_FOLD);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn unit_range() {
        let mut r = XorShift64Star::new(42);
        for _ in 0..10_000 {
            let u = r.next_unit();
            assert!((-0.5..0.5).contains(&u), "{u}");
        }
    }

    #[test]
    fn fnv_known_values() {
        // FNV-1a 64 reference: fnv1a64("") = offset basis.
        assert_eq!(fnv1a64(""), 0xCBF2_9CE4_8422_2325);
        // "a" = 0xaf63dc4c8601ec8c (published FNV-1a test vector)
        assert_eq!(fnv1a64("a"), 0xAF63_DC4C_8601_EC8C);
        assert_ne!(fnv1a64("mnist/0/weights"), fnv1a64("mnist/0/bias"));
    }

    #[test]
    fn fill_deterministic_and_scaled() {
        let a = fill_tensor("m", 0, "weights", 12, 2.0);
        let b = fill_tensor("m", 0, "weights", 12, 2.0);
        assert_eq!(a, b);
        let c = fill_tensor("m", 0, "weights", 12, 1.0);
        for (x, y) in a.iter().zip(&c) {
            assert!((x - 2.0 * y).abs() < 1e-6);
            assert!(y.abs() <= 0.5);
        }
    }

    #[test]
    fn next_below_in_range() {
        let mut r = XorShift64Star::new(7);
        for _ in 0..1000 {
            assert!(r.next_below(13) < 13);
        }
    }
}
