//! Bounded exhaustive model checker for the runtime's lock/condvar code.
//!
//! The offline build cannot pull `loom` from crates.io, so this module
//! implements the same idea in-tree: run a multi-threaded scenario under a
//! cooperative scheduler that serializes execution (one task runs at a
//! time), treat every synchronization operation as a *scheduling point*,
//! and DFS over the scheduler's choices so every interleaving — up to an
//! iterative preemption bound, CHESS-style — is actually executed.
//! `tests/loom_sync.rs` builds the real `Mailbox`/`QueueBank` against
//! these primitives via the `--cfg loom` switch in [`crate::util::sync`]
//! and asserts that no explored schedule deadlocks, and that weakening
//! `notify_all` to `notify_one` (the historical PR-1 lost-wakeup) *does*
//! deadlock.
//!
//! What the model covers:
//!
//! * [`sync::Mutex`] / [`sync::Condvar`] with no spurious wakeups — a
//!   waiter only runs again after a notification, which makes lost
//!   wakeups *observable as deadlocks* instead of being masked by the
//!   spurious wakeups real platforms are allowed to deliver.
//! * [`spawn`]/[`JoinHandle::join`] for scenario threads.
//! * `notify_one` branches over *which* waiter wakes (every choice is
//!   explored); `notify_all` wakes all waiters, unless the exploration
//!   runs with [`Config::weaken_notify_all`] — the switch the loom suite
//!   uses to prove the suite would catch the `notify_one` regression.
//! * Deadlock detection: a state with no runnable task and at least one
//!   alive blocked task aborts the execution and is counted in
//!   [`Stats::deadlocks`].
//!
//! Scheduling-point granularity is sync-op level (lock/unlock/wait/
//! notify/spawn/join), which is exact for code whose shared state is only
//! touched under locks — true for `Mailbox` and `QueueBank` by
//! construction.  Data races on unsynchronized memory are out of scope
//! (that is the ThreadSanitizer CI job's half of the wall).

use std::cell::{RefCell, UnsafeCell};
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex};

// ---------------------------------------------------------------- config

/// Exploration parameters.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Max preemptions (context switches away from a still-runnable task)
    /// per schedule.  2 suffices for the lost-wakeup bug class (CHESS's
    /// small-bound hypothesis); forced switches at blocking points are
    /// free, so producer/consumer hand-offs are fully explored even at 0.
    pub preemption_bound: u32,
    /// Safety valve on the number of executions; [`Stats::complete`] is
    /// false if the space was not exhausted within it.
    pub max_executions: u64,
    /// Make `notify_all` behave as `notify_one` (single explored waiter
    /// choice) — the regression switch for the PR-1 lost-wakeup class.
    pub weaken_notify_all: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            preemption_bound: 2,
            max_executions: 200_000,
            weaken_notify_all: false,
        }
    }
}

/// Exploration result.
#[derive(Debug, Clone, Copy, Default)]
pub struct Stats {
    /// Schedules actually executed.
    pub executions: u64,
    /// Schedules that reached a deadlock state.
    pub deadlocks: u64,
    /// True iff every schedule within the preemption bound was executed.
    pub complete: bool,
}

// ------------------------------------------------------------- internals

/// Panic payload used to unwind tasks out of an aborted execution
/// (deadlock found, or a sibling task failed an assertion).
struct ModelAbort;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum TaskState {
    Runnable,
    BlockedMutex(usize),
    BlockedCv(usize),
    BlockedJoin(usize),
    Finished,
}

/// One recorded scheduler choice.  `costs[i]` is the preemption cost of
/// candidate `i` at this point (1 = switching away from a still-runnable
/// current task); wake-choices cost 0.  Only points with >1 candidate are
/// recorded — forced moves replay deterministically.
#[derive(Clone, Debug)]
struct Decision {
    chosen: usize,
    options: usize,
    costs: Vec<u8>,
}

struct MutexState {
    held: Option<usize>,
}

struct CvState {
    waiters: Vec<usize>,
}

struct Kernel {
    tasks: Vec<TaskState>,
    mutexes: Vec<MutexState>,
    cvs: Vec<CvState>,
    decisions: Vec<Decision>,
    pos: usize,
    current: usize,
    weaken_notify_all: bool,
    aborting: bool,
    deadlocked: bool,
    /// First real (non-abort) panic message from any task.
    panicked: Option<String>,
}

impl Kernel {
    fn runnable(&self) -> Vec<usize> {
        (0..self.tasks.len())
            .filter(|&i| self.tasks[i] == TaskState::Runnable)
            .collect()
    }

    fn all_finished(&self) -> bool {
        self.tasks.iter().all(|t| *t == TaskState::Finished)
    }
}

struct Parker {
    run: StdMutex<bool>,
    cv: StdCondvar,
}

impl Parker {
    fn new() -> Self {
        Parker {
            run: StdMutex::new(false),
            cv: StdCondvar::new(),
        }
    }
}

struct Exec {
    kernel: StdMutex<Kernel>,
    parkers: StdMutex<Vec<Arc<Parker>>>,
    handles: StdMutex<Vec<std::thread::JoinHandle<()>>>,
    gen: usize,
}

thread_local! {
    static EXEC: RefCell<Option<Arc<Exec>>> = const { RefCell::new(None) };
    static TASK: RefCell<usize> = const { RefCell::new(usize::MAX) };
}

static GEN: AtomicUsize = AtomicUsize::new(1);

fn cur_exec() -> Arc<Exec> {
    EXEC.with(|e| {
        e.borrow()
            .clone()
            .expect("model sync primitive used outside model::explore")
    })
}

fn cur_task() -> usize {
    TASK.with(|t| *t.borrow())
}

fn panic_abort() -> ! {
    panic::panic_any(ModelAbort)
}

/// Silence the default panic hook for ModelAbort unwinds (thousands per
/// exploration); real panics keep the normal report.
fn install_quiet_hook() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<ModelAbort>().is_none() {
                prev(info);
            }
        }));
    });
}

impl Exec {
    fn new(cfg: &Config, prefix: Vec<Decision>) -> Arc<Exec> {
        Arc::new(Exec {
            kernel: StdMutex::new(Kernel {
                tasks: vec![TaskState::Runnable],
                mutexes: Vec::new(),
                cvs: Vec::new(),
                decisions: prefix,
                pos: 0,
                current: 0,
                weaken_notify_all: cfg.weaken_notify_all,
                aborting: false,
                deadlocked: false,
                panicked: None,
            }),
            parkers: StdMutex::new(vec![Arc::new(Parker::new())]),
            handles: StdMutex::new(Vec::new()),
            gen: GEN.fetch_add(1, Ordering::Relaxed),
        })
    }

    fn register_mutex(&self) -> usize {
        let mut k = self.kernel.lock().unwrap();
        k.mutexes.push(MutexState { held: None });
        k.mutexes.len() - 1
    }

    fn register_cv(&self) -> usize {
        let mut k = self.kernel.lock().unwrap();
        k.cvs.push(CvState { waiters: Vec::new() });
        k.cvs.len() - 1
    }

    /// Choose among `candidates`; `sched` choices carry preemption costs,
    /// wake choices are free.  Records only branching points.
    fn decide(&self, k: &mut Kernel, candidates: &[usize], sched: bool) -> usize {
        debug_assert!(!candidates.is_empty());
        // Forced moves are never recorded (and never consume a replayed
        // decision) so the decision list holds branch points only and
        // record/replay stay in lockstep.  A forced move always costs 0:
        // at a sched point the runnable current task is itself a
        // candidate, so a singleton candidate set IS the current task.
        if candidates.len() == 1 {
            return candidates[0];
        }
        let current_runnable = k.tasks.get(k.current) == Some(&TaskState::Runnable);
        let costs: Vec<u8> = candidates
            .iter()
            .map(|&c| u8::from(sched && current_runnable && c != k.current))
            .collect();
        let chosen = if k.pos < k.decisions.len() {
            let d = &k.decisions[k.pos];
            debug_assert_eq!(
                d.options,
                candidates.len(),
                "schedule replay diverged (nondeterministic scenario body?)"
            );
            d.chosen
        } else {
            // Canonical extension: the cheapest candidate (the current
            // task when it is runnable), so default runs add 0 preemptions.
            let c = costs.iter().position(|&c| c == 0).unwrap_or(0);
            k.decisions.push(Decision {
                chosen: c,
                options: candidates.len(),
                costs: costs.clone(),
            });
            c
        };
        k.pos += 1;
        candidates[chosen]
    }

    fn grant(&self, task: usize) {
        let p = {
            let parkers = self.parkers.lock().unwrap();
            Arc::clone(&parkers[task])
        };
        let mut g = p.run.lock().unwrap();
        *g = true;
        p.cv.notify_all();
    }

    /// Park the calling task until granted the run token; panics with
    /// ModelAbort if the execution is aborting.
    fn park(&self, me: usize) {
        let p = {
            let parkers = self.parkers.lock().unwrap();
            Arc::clone(&parkers[me])
        };
        let mut g = p.run.lock().unwrap();
        while !*g {
            g = p.cv.wait(g).unwrap();
        }
        *g = false;
        drop(g);
        let aborting = self.kernel.lock().unwrap().aborting;
        if aborting {
            panic_abort();
        }
    }

    /// Abort the whole execution (deadlock or task failure): wake every
    /// parked task so it unwinds via ModelAbort.
    fn abort_all(&self, k: &mut Kernel, deadlock: bool) {
        k.aborting = true;
        if deadlock {
            k.deadlocked = true;
        }
        let parkers = self.parkers.lock().unwrap();
        for p in parkers.iter() {
            *p.run.lock().unwrap() = true;
            p.cv.notify_all();
        }
    }

    /// Voluntary scheduling point: the current (runnable) task offers the
    /// scheduler a switch.
    fn schedule(&self) {
        let me = cur_task();
        let next = {
            let mut k = self.kernel.lock().unwrap();
            if k.aborting {
                drop(k);
                panic_abort();
            }
            let cands = k.runnable();
            let next = self.decide(&mut k, &cands, true);
            k.current = next;
            next
        };
        if next != me {
            self.grant(next);
            self.park(me);
        }
    }

    /// The current task just blocked (state already updated): hand the
    /// token to some runnable task, or declare a deadlock.
    fn switch_from_blocked(&self, k: &mut Kernel, me: usize) {
        let cands = k.runnable();
        if cands.is_empty() {
            // Everybody left alive is blocked — the lost-wakeup signature.
            self.abort_all(k, true);
            return; // caller drops the kernel lock, then parks -> aborts
        }
        let next = self.decide(k, &cands, true);
        k.current = next;
        self.grant(next);
    }

    fn acquire(&self, mid: usize) {
        let me = cur_task();
        loop {
            self.schedule();
            let mut k = self.kernel.lock().unwrap();
            if k.aborting {
                drop(k);
                panic_abort();
            }
            if k.mutexes[mid].held.is_none() {
                k.mutexes[mid].held = Some(me);
                return;
            }
            k.tasks[me] = TaskState::BlockedMutex(mid);
            self.switch_from_blocked(&mut k, me);
            drop(k);
            self.park(me);
        }
    }

    fn release(&self, mid: usize) {
        {
            let mut k = self.kernel.lock().unwrap();
            k.mutexes[mid].held = None;
            for i in 0..k.tasks.len() {
                if k.tasks[i] == TaskState::BlockedMutex(mid) {
                    k.tasks[i] = TaskState::Runnable;
                }
            }
            // Guard drops run during ModelAbort unwinds; never schedule
            // (or panic) from inside one.
            if k.aborting {
                return;
            }
        }
        self.schedule();
    }

    fn cv_wait(&self, cvid: usize, mid: usize) {
        let me = cur_task();
        {
            let mut k = self.kernel.lock().unwrap();
            if k.aborting {
                drop(k);
                panic_abort();
            }
            debug_assert_eq!(k.mutexes[mid].held, Some(me), "wait without the lock");
            k.mutexes[mid].held = None;
            for i in 0..k.tasks.len() {
                if k.tasks[i] == TaskState::BlockedMutex(mid) {
                    k.tasks[i] = TaskState::Runnable;
                }
            }
            k.cvs[cvid].waiters.push(me);
            k.tasks[me] = TaskState::BlockedCv(cvid);
            self.switch_from_blocked(&mut k, me);
        }
        self.park(me);
        // Notified (no spurious wakeups): re-acquire the mutex.
        self.acquire(mid);
    }

    fn notify(&self, cvid: usize, all: bool) {
        {
            let mut k = self.kernel.lock().unwrap();
            if k.aborting {
                drop(k);
                panic_abort();
            }
            let as_all = all && !k.weaken_notify_all;
            if k.cvs[cvid].waiters.is_empty() {
                // nothing to wake
            } else if as_all {
                let waiters = std::mem::take(&mut k.cvs[cvid].waiters);
                for w in waiters {
                    k.tasks[w] = TaskState::Runnable;
                }
            } else {
                // Which waiter receives the single token is a scheduler
                // choice — every option is explored.
                let cands = k.cvs[cvid].waiters.clone();
                let woken = self.decide(&mut k, &cands, false);
                k.cvs[cvid].waiters.retain(|&w| w != woken);
                k.tasks[woken] = TaskState::Runnable;
            }
        }
        self.schedule();
    }

    fn spawn_task(self: &Arc<Self>, f: Box<dyn FnOnce() + Send>) -> usize {
        let id = {
            let mut k = self.kernel.lock().unwrap();
            k.tasks.push(TaskState::Runnable);
            k.tasks.len() - 1
        };
        self.parkers.lock().unwrap().push(Arc::new(Parker::new()));
        let exec = Arc::clone(self);
        // lint: allow(thread-spawn): model tasks are real OS threads the
        // checker parks/resumes one at a time — they never compute jobs.
        let handle = std::thread::Builder::new()
            .name(format!("model-task-{id}"))
            .spawn(move || {
                EXEC.with(|e| *e.borrow_mut() = Some(Arc::clone(&exec)));
                TASK.with(|t| *t.borrow_mut() = id);
                // Wait to be scheduled for the first time.  An aborting
                // execution unwinds here before f ever runs.
                let body = AssertUnwindSafe(|| {
                    exec.park(id);
                    f();
                });
                let result = panic::catch_unwind(body);
                let real_panic = match result {
                    Ok(()) => None,
                    Err(p) if p.downcast_ref::<ModelAbort>().is_some() => None,
                    Err(p) => Some(panic_message(&p)),
                };
                exec.task_finished(id, real_panic);
                EXEC.with(|e| *e.borrow_mut() = None);
            })
            .expect("spawn model task thread");
        self.handles.lock().unwrap().push(handle);
        // The child is schedulable from here on.
        self.schedule();
        id
    }

    fn task_finished(&self, id: usize, real_panic: Option<String>) {
        let mut k = self.kernel.lock().unwrap();
        k.tasks[id] = TaskState::Finished;
        for i in 0..k.tasks.len() {
            if k.tasks[i] == TaskState::BlockedJoin(id) {
                k.tasks[i] = TaskState::Runnable;
            }
        }
        if let Some(msg) = real_panic {
            if k.panicked.is_none() {
                k.panicked = Some(msg);
            }
            self.abort_all(&mut k, false);
            return;
        }
        if k.aborting {
            return;
        }
        let cands = k.runnable();
        if cands.is_empty() {
            if k.all_finished() {
                // Hand the token back to main, which parks in finish_main.
                drop(k);
                self.grant(0);
                return;
            }
            self.abort_all(&mut k, true);
            return;
        }
        let next = self.decide(&mut k, &cands, true);
        k.current = next;
        drop(k);
        self.grant(next);
    }

    fn join_task(&self, target: usize) {
        let me = cur_task();
        loop {
            let mut k = self.kernel.lock().unwrap();
            if k.aborting {
                drop(k);
                panic_abort();
            }
            if k.tasks[target] == TaskState::Finished {
                return;
            }
            k.tasks[me] = TaskState::BlockedJoin(target);
            self.switch_from_blocked(&mut k, me);
            drop(k);
            self.park(me);
        }
    }

    /// Main's closure returned: let every remaining task run to
    /// completion, then return.  (Scenarios normally join everything
    /// themselves, making this a no-op.)
    fn finish_main(&self) {
        {
            let mut k = self.kernel.lock().unwrap();
            k.tasks[0] = TaskState::Finished;
            if k.all_finished() || k.aborting {
                return;
            }
            let cands = k.runnable();
            if cands.is_empty() {
                self.abort_all(&mut k, true);
                return;
            }
            let next = self.decide(&mut k, &cands, true);
            k.current = next;
            self.grant(next);
        }
        // Park until the last task finishes (it grants task 0) or abort.
        let p = {
            let parkers = self.parkers.lock().unwrap();
            Arc::clone(&parkers[0])
        };
        let mut g = p.run.lock().unwrap();
        while !*g {
            g = p.cv.wait(g).unwrap();
        }
        *g = false;
    }

    fn join_all_threads(&self) {
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            // Threads unwound by ModelAbort report a panic; that is the
            // abort mechanism working, not a failure.
            let _ = h.join();
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked (non-string payload)".to_string()
    }
}

// --------------------------------------------------------------- explore

/// Run `body` under every schedule within the preemption bound.  `body`
/// executes once per schedule on the calling thread (task 0); scenario
/// threads come from [`spawn`].  A deadlock aborts that schedule and is
/// counted; a real panic in any task fails the exploration by re-raising.
pub fn explore(cfg: Config, body: impl Fn()) -> Stats {
    install_quiet_hook();
    let mut stats = Stats::default();
    let mut prefix: Vec<Decision> = Vec::new();
    loop {
        let exec = Exec::new(&cfg, prefix);
        EXEC.with(|e| *e.borrow_mut() = Some(Arc::clone(&exec)));
        TASK.with(|t| *t.borrow_mut() = 0);
        let outcome = panic::catch_unwind(AssertUnwindSafe(&body));
        match &outcome {
            Ok(()) => exec.finish_main(),
            Err(p) if p.downcast_ref::<ModelAbort>().is_some() => {}
            Err(_) => {
                let mut k = exec.kernel.lock().unwrap();
                k.tasks[0] = TaskState::Finished;
                exec.abort_all(&mut k, false);
            }
        }
        exec.join_all_threads();
        EXEC.with(|e| *e.borrow_mut() = None);
        TASK.with(|t| *t.borrow_mut() = usize::MAX);

        let kernel = exec.kernel.lock().unwrap();
        stats.executions += 1;
        if kernel.deadlocked {
            stats.deadlocks += 1;
        }
        if let Some(msg) = &kernel.panicked {
            panic!("model task failed: {msg}");
        }
        if let Err(p) = outcome {
            if p.downcast_ref::<ModelAbort>().is_none() {
                panic::resume_unwind(p);
            }
        }
        prefix = kernel.decisions.clone();
        drop(kernel);
        if !advance(&mut prefix, cfg.preemption_bound) {
            stats.complete = true;
            break;
        }
        if stats.executions >= cfg.max_executions {
            break;
        }
    }
    stats
}

/// DFS step: bump the deepest decision that still has an untried option
/// within the preemption budget; canonical extensions below it cost 0.
fn advance(d: &mut Vec<Decision>, bound: u32) -> bool {
    for i in (0..d.len()).rev() {
        let base: u32 = d[..i].iter().map(|x| u32::from(x.costs[x.chosen])).sum();
        let next = ((d[i].chosen + 1)..d[i].options)
            .find(|&c| base + u32::from(d[i].costs[c]) <= bound);
        if let Some(c) = next {
            d[i].chosen = c;
            d.truncate(i + 1);
            return true;
        }
    }
    false
}

/// Spawn a scenario task.  Must be called from inside [`explore`].
pub fn spawn(f: impl FnOnce() + Send + 'static) -> JoinHandle {
    let exec = cur_exec();
    let id = exec.spawn_task(Box::new(f));
    JoinHandle { id }
}

/// Handle for [`spawn`]ed tasks; `join` blocks under model scheduling.
pub struct JoinHandle {
    id: usize,
}

impl JoinHandle {
    pub fn join(self) {
        cur_exec().join_task(self.id);
    }
}

// ------------------------------------------------------------ primitives

/// Model-checked `Mutex`/`Condvar` with the std surface the facade in
/// [`crate::util::sync`] needs.
pub mod sync {
    use super::*;
    use std::mem::ManuallyDrop;
    use std::ops::{Deref, DerefMut};

    /// Registration cell: objects created in one execution and reused in
    /// the next (e.g. statics) re-register lazily per execution.
    type Reg = StdMutex<Option<(usize, usize)>>;

    fn resolve(reg: &Reg, exec: &Arc<Exec>, register: impl FnOnce() -> usize) -> usize {
        let mut slot = reg.lock().unwrap();
        match *slot {
            Some((gen, id)) if gen == exec.gen => id,
            _ => {
                let id = register();
                *slot = Some((exec.gen, id));
                id
            }
        }
    }

    pub struct Mutex<T> {
        data: UnsafeCell<T>,
        reg: Reg,
    }

    // One task runs at a time and the model enforces mutual exclusion, so
    // handing references across the (serialized) scenario threads is
    // sound for the same reason it is for std::sync::Mutex.
    unsafe impl<T: Send> Send for Mutex<T> {}
    unsafe impl<T: Send> Sync for Mutex<T> {}

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Self {
            Mutex {
                data: UnsafeCell::new(t),
                reg: StdMutex::new(None),
            }
        }

        pub fn lock(&self) -> MutexGuard<'_, T> {
            let exec = cur_exec();
            let mid = resolve(&self.reg, &exec, || exec.register_mutex());
            exec.acquire(mid);
            MutexGuard {
                mutex: self,
                exec,
                mid,
            }
        }
    }

    impl<T: Default> Default for Mutex<T> {
        fn default() -> Self {
            Mutex::new(T::default())
        }
    }

    pub struct MutexGuard<'a, T> {
        mutex: &'a Mutex<T>,
        exec: Arc<Exec>,
        mid: usize,
    }

    impl<T> Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            unsafe { &*self.mutex.data.get() }
        }
    }

    impl<T> DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            unsafe { &mut *self.mutex.data.get() }
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            self.exec.release(self.mid);
        }
    }

    #[derive(Default)]
    pub struct Condvar {
        reg: Reg,
    }

    impl Condvar {
        pub const fn new() -> Self {
            Condvar {
                reg: StdMutex::new(None),
            }
        }

        fn cvid(&self, exec: &Arc<Exec>) -> usize {
            resolve(&self.reg, exec, || exec.register_cv())
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
            let guard = ManuallyDrop::new(guard);
            let mutex = guard.mutex;
            let mid = guard.mid;
            let exec = Arc::clone(&guard.exec);
            let cvid = self.cvid(&exec);
            // The wait releases and re-acquires the lock itself; the old
            // guard must not run its Drop.
            exec.cv_wait(cvid, mid);
            MutexGuard { mutex, exec, mid }
        }

        pub fn notify_one(&self) {
            let exec = cur_exec();
            let cvid = self.cvid(&exec);
            exec.notify(cvid, false);
        }

        pub fn notify_all(&self) {
            let exec = cur_exec();
            let cvid = self.cvid(&exec);
            exec.notify(cvid, true);
        }
    }
}

// The model's own regression suite runs in the NORMAL test build (no
// --cfg loom needed): the checker is plain library code.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::sync::{Condvar, Mutex};
    use super::*;

    #[test]
    fn serialized_counter_sees_all_increments() {
        let stats = explore(Config::default(), || {
            let counter = Arc::new(Mutex::new(0u32));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let c = Arc::clone(&counter);
                    spawn(move || {
                        *c.lock() += 1;
                    })
                })
                .collect();
            for h in handles {
                h.join();
            }
            assert_eq!(*counter.lock(), 2);
        });
        assert!(stats.complete, "space must be exhausted: {stats:?}");
        assert_eq!(stats.deadlocks, 0, "{stats:?}");
        assert!(stats.executions > 1, "must explore >1 interleaving");
    }

    /// Textbook lost wakeup: two waiters, one token, `notify_one`.  The
    /// checker must find the schedule where the wrong waiter... there is
    /// no wrong waiter to *wake* — the second notify is never sent, so
    /// one waiter sleeps forever.
    #[test]
    fn detects_lost_wakeup_deadlock() {
        let stats = explore(Config::default(), || {
            let m = Arc::new(Mutex::new(0u32));
            let cv = Arc::new(Condvar::new());
            let waiters: Vec<_> = (0..2)
                .map(|_| {
                    let m = Arc::clone(&m);
                    let cv = Arc::clone(&cv);
                    spawn(move || {
                        let mut g = m.lock();
                        while *g == 0 {
                            g = cv.wait(g);
                        }
                    })
                })
                .collect();
            {
                let mut g = m.lock();
                *g = 1;
            }
            // One notification for two waiters: whichever order the
            // waiters parked, somebody is never woken.
            cv.notify_one();
            for w in waiters {
                w.join();
            }
        });
        assert!(stats.deadlocks > 0, "lost wakeup not detected: {stats:?}");
    }

    /// Same scenario with notify_all: no schedule deadlocks.
    #[test]
    fn notify_all_releases_every_waiter() {
        let stats = explore(Config::default(), || {
            let m = Arc::new(Mutex::new(0u32));
            let cv = Arc::new(Condvar::new());
            let waiters: Vec<_> = (0..2)
                .map(|_| {
                    let m = Arc::clone(&m);
                    let cv = Arc::clone(&cv);
                    spawn(move || {
                        let mut g = m.lock();
                        while *g == 0 {
                            g = cv.wait(g);
                        }
                    })
                })
                .collect();
            {
                let mut g = m.lock();
                *g = 1;
            }
            cv.notify_all();
            for w in waiters {
                w.join();
            }
        });
        assert!(stats.complete, "{stats:?}");
        assert_eq!(stats.deadlocks, 0, "notify_all must not deadlock: {stats:?}");
    }

    /// The weaken switch turns the passing scenario above into the failing
    /// one — this is the mechanism `tests/loom_sync.rs` uses to prove the
    /// suite guards the regression.
    #[test]
    fn weaken_switch_downgrades_notify_all() {
        let cfg = Config {
            weaken_notify_all: true,
            ..Config::default()
        };
        let stats = explore(cfg, || {
            let m = Arc::new(Mutex::new(0u32));
            let cv = Arc::new(Condvar::new());
            let waiters: Vec<_> = (0..2)
                .map(|_| {
                    let m = Arc::clone(&m);
                    let cv = Arc::clone(&cv);
                    spawn(move || {
                        let mut g = m.lock();
                        while *g == 0 {
                            g = cv.wait(g);
                        }
                    })
                })
                .collect();
            {
                let mut g = m.lock();
                *g = 1;
            }
            cv.notify_all();
            for w in waiters {
                w.join();
            }
        });
        assert!(
            stats.deadlocks > 0,
            "weakened notify_all must lose a wakeup: {stats:?}"
        );
    }

    /// A failing assertion inside a scenario task must fail the test, not
    /// vanish into a swallowed thread panic.
    #[test]
    fn task_panics_propagate() {
        let caught = std::panic::catch_unwind(|| {
            explore(Config::default(), || {
                let h = spawn(|| panic!("scenario invariant violated"));
                h.join();
            });
        });
        assert!(caught.is_err(), "task panic must propagate");
    }

    /// Mutex hand-off explores both acquisition orders.
    #[test]
    fn contended_lock_explores_both_orders() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let saw_a_first = Arc::new(AtomicBool::new(false));
        let saw_b_first = Arc::new(AtomicBool::new(false));
        let (a, b) = (Arc::clone(&saw_a_first), Arc::clone(&saw_b_first));
        let stats = explore(Config::default(), move || {
            let m = Arc::new(Mutex::new(Vec::<u8>::new()));
            let ha = {
                let m = Arc::clone(&m);
                spawn(move || m.lock().push(b'a'))
            };
            let hb = {
                let m = Arc::clone(&m);
                spawn(move || m.lock().push(b'b'))
            };
            ha.join();
            hb.join();
            let order = m.lock().clone();
            match order.as_slice() {
                [b'a', b'b'] => a.store(true, Ordering::Relaxed),
                [b'b', b'a'] => b.store(true, Ordering::Relaxed),
                other => panic!("lost an increment: {other:?}"),
            }
        });
        assert!(stats.complete);
        assert!(saw_a_first.load(Ordering::Relaxed), "a-first order missed");
        assert!(saw_b_first.load(Ordering::Relaxed), "b-first order missed");
    }
}
