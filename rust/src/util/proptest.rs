//! Mini property-testing harness (no proptest crate offline).
//!
//! `check(name, cases, |g| { ... })` runs a property over `cases` random
//! generators; on failure it reports the seed so the case can be replayed
//! deterministically with `replay(seed, |g| ...)`.

use super::rng::XorShift64Star;

/// Random-value source handed to properties.
pub struct Gen {
    rng: XorShift64Star,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: XorShift64Star::new(seed),
            seed,
        }
    }

    /// usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_unit(&mut self) -> f32 {
        self.rng.next_unit() as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_unit() * 2.0).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `prop` over `cases` seeded generators; panic with the failing seed.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    for case in 0..cases {
        // Derived, stable seeds: base on the property name + case index.
        let seed = super::rng::fnv1a64(name) ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnOnce(&mut Gen)>(seed: u64, prop: F) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_true_property() {
        check("sum-commutes", 50, |g| {
            let a = g.f32_unit();
            let b = g.f32_unit();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn check_reports_seed_on_failure() {
        check("always-fails", 3, |_g| {
            panic!("intentional");
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
        }
        let v = g.vec_f32(10);
        assert_eq!(v.len(), 10);
        let items = [1, 2, 3];
        for _ in 0..10 {
            assert!(items.contains(g.choose(&items)));
        }
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = Vec::new();
        replay(42, |g| {
            first = g.vec_f32(5);
        });
        let mut second = Vec::new();
        replay(42, |g| {
            second = g.vec_f32(5);
        });
        assert_eq!(first, second);
    }
}
