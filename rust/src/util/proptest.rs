//! Mini property-testing harness (no proptest crate offline).
//!
//! `check(name, cases, |g| { ... })` runs a property over `cases` random
//! generators; on failure it reports the seed so the case can be replayed
//! deterministically with `replay(seed, |g| ...)`.
//!
//! Seed diversity: setting `SCHED_SEED=<n>` in the environment folds `n`
//! into every derived seed, so the same properties explore a fresh
//! deterministic case family per value — CI runs the deterministic
//! scheduling suite under a small `SCHED_SEED` matrix on every push,
//! instead of forever retesting one hardcoded family.  Unset (or `0`)
//! keeps the historical seeds; any failure report names the value to
//! reproduce with.

use super::rng::XorShift64Star;

/// Extra seed entropy from the `SCHED_SEED` environment variable (0 when
/// unset or unparseable — the historical seed family).
pub fn env_seed_salt() -> u64 {
    std::env::var("SCHED_SEED")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0)
}

/// Random-value source handed to properties.
pub struct Gen {
    rng: XorShift64Star,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: XorShift64Star::new(seed),
            seed,
        }
    }

    /// usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_unit(&mut self) -> f32 {
        self.rng.next_unit() as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_unit() * 2.0).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `prop` over `cases` seeded generators; panic with the failing seed.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    let salt = env_seed_salt();
    for case in 0..cases {
        // Derived, stable seeds: property name + case index, plus the
        // optional SCHED_SEED family selector (0 = the historical seeds).
        let seed = super::rng::fnv1a64(name)
            ^ (case as u64).wrapping_mul(0x9E37_79B9)
            ^ salt.wrapping_mul(0x517C_C1B7_2722_0A95);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} \
                 (seed {seed:#x}, SCHED_SEED={salt}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnOnce(&mut Gen)>(seed: u64, prop: F) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_true_property() {
        check("sum-commutes", 50, |g| {
            let a = g.f32_unit();
            let b = g.f32_unit();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn check_reports_seed_on_failure() {
        check("always-fails", 3, |_g| {
            panic!("intentional");
        });
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
        }
        let v = g.vec_f32(10);
        assert_eq!(v.len(), 10);
        let items = [1, 2, 3];
        for _ in 0..10 {
            assert!(items.contains(g.choose(&items)));
        }
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = Vec::new();
        replay(42, |g| {
            first = g.vec_f32(5);
        });
        let mut second = Vec::new();
        replay(42, |g| {
            second = g.vec_f32(5);
        });
        assert_eq!(first, second);
    }
}
