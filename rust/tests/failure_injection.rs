//! Failure injection: the coordinator must fail loudly and cleanly, never
//! hang or corrupt, when components misbehave — queues closed mid-stream,
//! missing artifacts, malformed configs, oversized architectures.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use synergy::accel::{Accelerator, NativeGemm};
use synergy::cluster::{JobQueue, QueueBank};
use synergy::config::{zoo, HwConfig, NetConfig};
use synergy::hwgen;
use synergy::mm::job::{ClassMask, Job, JobClass, JobResult};
use synergy::nn::Network;
use synergy::rt::delegate::{self, DelegateStats, RtJob};
use synergy::runtime::{Manifest, PeEngine};
use synergy::sched::worksteal::{Thief, ThiefMsg};
use synergy::util::rng::XorShift64Star;

#[test]
fn queue_closed_while_consumers_blocked_unblocks_all() {
    let q: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
    let consumers: Vec<_> = (0..4)
        .map(|_| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.pop_blocking())
        })
        .collect();
    std::thread::sleep(Duration::from_millis(20));
    q.close();
    for c in consumers {
        assert_eq!(c.join().unwrap(), None);
    }
}

#[test]
fn thief_survives_queues_closed_under_it() {
    let q0: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
    let q1: Arc<QueueBank<u32>> = Arc::new(QueueBank::new());
    for i in 0..100 {
        q1.push(i);
    }
    let thief = Thief::spawn(vec![Arc::clone(&q0), Arc::clone(&q1)]);
    let tx = thief.sender();
    // close the destination bank, then demand steals into it
    q0.close();
    for _ in 0..10 {
        tx.send(ThiefMsg::ClusterIdle(0, ClassMask::all())).unwrap();
    }
    std::thread::sleep(Duration::from_millis(20));
    // jobs must not be lost: still in q1 OR rejected push left them stolen…
    // the contract is: push_batch to a closed bank returns false and the
    // thief does not count it as success; nothing hangs.
    thief.shutdown();
    q1.close();
    let mut drained = 0;
    while q1.try_pop_any(ClassMask::all()).is_some() {
        drained += 1;
    }
    assert!(drained <= 100);
}

/// A PE backend that dies after `fail_after` jobs — the injected failure.
struct FlakyPe {
    remaining: usize,
}

impl Accelerator for FlakyPe {
    fn id(&self) -> &str {
        "flaky-pe"
    }

    fn supports(&self, class: JobClass) -> bool {
        class == JobClass::ConvTile
    }

    fn execute(&mut self, job: &Job) -> Result<JobResult> {
        if self.remaining == 0 {
            anyhow::bail!("injected PE failure");
        }
        self.remaining -= 1;
        Ok(job.execute_native())
    }
}

/// Mixed-cluster failure: the cluster's only PE member dies mid-run.  The
/// NEON member shares the same bank through its own mask, so FC/im2col
/// service must continue with zero lost jobs — and the conv job the PE
/// was holding is DROPPED fail-fast (its rescue mask says no survivor
/// speaks CONV), closing its reply channel instead of stranding it on a
/// bank nobody can drain.  (The requeue side of the failure contract —
/// a survivor that CAN take the work — is pinned by
/// `rt::delegate::tests::failing_backend_requeues_its_run` and
/// `tests/remote_shard.rs`.)
#[test]
fn pe_death_does_not_lose_fc_or_im2col_jobs() {
    let bank: Arc<QueueBank<RtJob>> = Arc::new(QueueBank::new());

    // The PE member: conv-only mask, fails on its 4th job.  Its rescue
    // mask is the NEON teammate's capability set — no survivor for CONV.
    let pe_stats = Arc::new(DelegateStats::default());
    let pe_handle = delegate::spawn(
        "flaky-pe".into(),
        0,
        Arc::clone(&bank),
        ClassMask::of(&[JobClass::ConvTile]),
        ClassMask::of(&[JobClass::FcGemm, JobClass::Im2col]),
        || Ok(Box::new(FlakyPe { remaining: 3 }) as Box<dyn Accelerator>),
        None,
        Arc::clone(&pe_stats),
        0,
    );
    // The NEON member: restricted here to FC + im2col so the division of
    // labor (and therefore the failure blast radius) is deterministic.
    let neon_stats = Arc::new(DelegateStats::default());
    let neon_handle = delegate::spawn(
        "neon".into(),
        0,
        Arc::clone(&bank),
        ClassMask::of(&[JobClass::FcGemm, JobClass::Im2col]),
        ClassMask::of(&[JobClass::ConvTile]),
        || Ok(Box::new(NativeGemm) as Box<dyn Accelerator>),
        None,
        Arc::clone(&neon_stats),
        0,
    );

    // 6 conv jobs (the PE dies on the 4th) + a continuing FC/im2col load.
    let (conv_tx, conv_rx) = std::sync::mpsc::channel();
    let grid = synergy::mm::TileGrid::new(32, 64, 32, 32);
    let a = Arc::new(XorShift64Star::new(1).fill_f32(32 * 64, 1.0));
    let b = Arc::new(XorShift64Star::new(2).fill_f32(64 * 32, 1.0));
    let mut id = 0;
    for _ in 0..6 {
        let jobs =
            synergy::mm::job::jobs_for_gemm(0, 0, grid, Arc::clone(&a), Arc::clone(&b), &mut id);
        for job in jobs {
            bank.push(RtJob {
                job,
                reply: conv_tx.clone(),
            });
        }
    }
    let (fcim_tx, fcim_rx) = std::sync::mpsc::channel();
    let n_fc = 8;
    let n_im2col = 8;
    for i in 0..n_fc {
        let w = Arc::new(XorShift64Star::new(100 + i).fill_f32(16 * 24, 1.0));
        let x = Arc::new(XorShift64Star::new(200 + i).fill_f32(24, 1.0));
        bank.push(RtJob {
            job: Job::fc(id, 1, i, 16, 24, w, x, 32),
            reply: fcim_tx.clone(),
        });
        id += 1;
    }
    for i in 0..n_im2col {
        let input = Arc::new(XorShift64Star::new(300 + i).fill_f32(3 * 8 * 8, 1.0));
        bank.push(RtJob {
            job: Job::im2col(id, 0, i, (3, 8, 8), 3, 1, 1, input, 32),
            reply: fcim_tx.clone(),
        });
        id += 1;
    }
    drop(conv_tx);
    drop(fcim_tx);

    // Every FC and im2col job completes — the PE's death is invisible to
    // the classes the NEON member serves.
    let mut fcim_done = 0;
    for _ in 0..(n_fc + n_im2col) {
        fcim_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("FC/im2col job lost after PE death");
        fcim_done += 1;
    }
    assert_eq!(fcim_done, n_fc + n_im2col);

    // The PE executed exactly 3 conv jobs, then died holding the 4th —
    // no survivor speaks CONV, so that job is dropped fail-fast (its
    // reply sender closes) rather than requeued onto a bank nobody can
    // drain.
    let mut conv_done = 0;
    while conv_rx.recv_timeout(Duration::from_millis(100)).is_ok() {
        conv_done += 1;
    }
    assert_eq!(conv_done, 3, "PE must have served 3 conv jobs before dying");
    let err = pe_handle.join().unwrap().expect_err("PE must die");
    assert!(err.to_string().contains("injected"), "{err}");
    assert_eq!(pe_stats.jobs_by_class()[JobClass::ConvTile.index()], 3);
    assert_eq!(pe_stats.jobs.load(std::sync::atomic::Ordering::Relaxed), 3);
    assert_eq!(
        pe_stats.requeued.load(std::sync::atomic::Ordering::Relaxed),
        0,
        "a job with no surviving capable member must not be requeued"
    );

    // The NEON member is still alive and serving; shut it down cleanly.
    bank.close();
    neon_handle.join().unwrap().unwrap();
    let by_class = neon_stats.jobs_by_class();
    assert_eq!(by_class[JobClass::FcGemm.index()], n_fc);
    assert_eq!(by_class[JobClass::Im2col.index()], n_im2col);
    assert_eq!(by_class[JobClass::ConvTile.index()], 0);
    // 6 GEMM pushes × 1 tile each = 6 conv jobs; 3 executed, 1 dropped
    // fail-fast on the PE's death, 2 never popped and still queued.
    assert_eq!(
        bank.class_counts()[JobClass::ConvTile.index()],
        2,
        "undrained conv backlog after close"
    );
}

#[test]
fn missing_artifacts_is_a_clean_error() {
    let bogus = std::path::Path::new("/nonexistent/synergy-artifacts");
    let err = match PeEngine::load(bogus, None) {
        Ok(_) => panic!("load from bogus path must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("manifest") || err.contains("reading"), "{err}");
    let err2 = Manifest::load(bogus).unwrap_err().to_string();
    assert!(err2.contains("make artifacts"), "{err2}");
}

#[test]
fn malformed_manifest_rejected() {
    for bad in ["", "{", "[]", r#"{"tile_size": "x"}"#, r#"{"tile_size": 32}"#] {
        assert!(Manifest::parse(bad).is_err(), "accepted {bad:?}");
    }
}

#[test]
fn oversized_hwgen_config_fails_before_writing() {
    let mut hw = HwConfig::default_zc702();
    hw.clusters[1].pes[0].1 = 98;
    hw.memsub.mmus = 50;
    let dir = std::env::temp_dir().join(format!("synergy_fail_{}", std::process::id()));
    assert!(hwgen::generate(&hw, &dir).is_err());
    // nothing half-written
    assert!(!dir.join("wiring.json").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn degenerate_network_configs_rejected() {
    // conv after flatten
    let cfg = NetConfig::parse(
        "bad",
        "[net]\nheight=8\nwidth=8\nchannels=1\n[connected]\noutput=4\n[convolutional]\nfilters=2\nsize=3\n",
    )
    .unwrap();
    assert!(Network::new(cfg, 32).is_err());
    // pool larger than input
    let cfg = NetConfig::parse(
        "bad2",
        "[net]\nheight=2\nwidth=2\nchannels=1\n[maxpool]\nsize=5\n",
    )
    .unwrap();
    assert!(Network::new(cfg, 32).is_err());
    // kernel larger than padded input
    let cfg = NetConfig::parse(
        "bad3",
        "[net]\nheight=2\nwidth=2\nchannels=1\n[convolutional]\nfilters=1\nsize=7\n",
    )
    .unwrap();
    assert!(Network::new(cfg, 32).is_err());
}

#[test]
fn zero_frames_stream_terminates() {
    use synergy::rt::{driver::run_stream, RtOptions};
    let net = Arc::new(Network::new(zoo::load("mpcnn").unwrap(), 32).unwrap());
    let report = run_stream(net, RtOptions::default(), Vec::new()).unwrap();
    assert_eq!(report.outputs.len(), 0);
    assert_eq!(report.jobs_executed, 0);
}
