//! Failure injection: the coordinator must fail loudly and cleanly, never
//! hang or corrupt, when components misbehave — queues closed mid-stream,
//! missing artifacts, malformed configs, oversized architectures.

use std::sync::Arc;
use std::time::Duration;

use synergy::cluster::JobQueue;
use synergy::config::{zoo, HwConfig, NetConfig};
use synergy::hwgen;
use synergy::nn::Network;
use synergy::runtime::{Manifest, PeEngine};
use synergy::sched::worksteal::{Thief, ThiefMsg};

#[test]
fn queue_closed_while_consumers_blocked_unblocks_all() {
    let q: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
    let consumers: Vec<_> = (0..4)
        .map(|_| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.pop_blocking())
        })
        .collect();
    std::thread::sleep(Duration::from_millis(20));
    q.close();
    for c in consumers {
        assert_eq!(c.join().unwrap(), None);
    }
}

#[test]
fn thief_survives_queues_closed_under_it() {
    let q0: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
    let q1: Arc<JobQueue<u32>> = Arc::new(JobQueue::new());
    for i in 0..100 {
        q1.push(i);
    }
    let thief = Thief::spawn(vec![Arc::clone(&q0), Arc::clone(&q1)]);
    let tx = thief.sender();
    // close the destination queue, then demand steals into it
    q0.close();
    for _ in 0..10 {
        tx.send(ThiefMsg::ClusterIdle(0)).unwrap();
    }
    std::thread::sleep(Duration::from_millis(20));
    // jobs must not be lost: still in q1 OR rejected push left them stolen…
    // the contract is: push_batch to a closed queue returns false and the
    // thief does not count it as success; nothing hangs.
    thief.shutdown();
    q1.close();
    let mut drained = 0;
    while q1.pop_blocking().is_some() {
        drained += 1;
    }
    assert!(drained <= 100);
}

#[test]
fn missing_artifacts_is_a_clean_error() {
    let bogus = std::path::Path::new("/nonexistent/synergy-artifacts");
    let err = match PeEngine::load(bogus, None) {
        Ok(_) => panic!("load from bogus path must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("manifest") || err.contains("reading"), "{err}");
    let err2 = Manifest::load(bogus).unwrap_err().to_string();
    assert!(err2.contains("make artifacts"), "{err2}");
}

#[test]
fn malformed_manifest_rejected() {
    for bad in ["", "{", "[]", r#"{"tile_size": "x"}"#, r#"{"tile_size": 32}"#] {
        assert!(Manifest::parse(bad).is_err(), "accepted {bad:?}");
    }
}

#[test]
fn oversized_hwgen_config_fails_before_writing() {
    let mut hw = HwConfig::default_zc702();
    hw.clusters[1].pes[0].1 = 98;
    hw.memsub.mmus = 50;
    let dir = std::env::temp_dir().join(format!("synergy_fail_{}", std::process::id()));
    assert!(hwgen::generate(&hw, &dir).is_err());
    // nothing half-written
    assert!(!dir.join("wiring.json").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn degenerate_network_configs_rejected() {
    // conv after flatten
    let cfg = NetConfig::parse(
        "bad",
        "[net]\nheight=8\nwidth=8\nchannels=1\n[connected]\noutput=4\n[convolutional]\nfilters=2\nsize=3\n",
    )
    .unwrap();
    assert!(Network::new(cfg, 32).is_err());
    // pool larger than input
    let cfg = NetConfig::parse(
        "bad2",
        "[net]\nheight=2\nwidth=2\nchannels=1\n[maxpool]\nsize=5\n",
    )
    .unwrap();
    assert!(Network::new(cfg, 32).is_err());
    // kernel larger than padded input
    let cfg = NetConfig::parse(
        "bad3",
        "[net]\nheight=2\nwidth=2\nchannels=1\n[convolutional]\nfilters=1\nsize=7\n",
    )
    .unwrap();
    assert!(Network::new(cfg, 32).is_err());
}

#[test]
fn zero_frames_stream_terminates() {
    use synergy::rt::{driver::run_stream, RtOptions};
    let net = Arc::new(Network::new(zoo::load("mpcnn").unwrap(), 32).unwrap());
    let report = run_stream(net, RtOptions::default(), Vec::new()).unwrap();
    assert_eq!(report.outputs.len(), 0);
    assert_eq!(report.jobs_executed, 0);
}
