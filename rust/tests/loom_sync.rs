//! Exhaustive interleaving checks for `Mailbox` and `QueueBank` under the
//! in-tree model checker (`util::model`) — the loom wall.
//!
//! Build and run with:
//!
//! ```sh
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_sync
//! ```
//!
//! Under `--cfg loom`, `util::sync` rebinds `Mutex`/`Condvar` to the model
//! scheduler, so the *production* `Mailbox`/`QueueBank` code — not a
//! replica — runs under every explored schedule up to the preemption
//! bound.  Each scenario has two forms:
//!
//! * the shipped code, asserted deadlock-free over a **complete**
//!   exploration (`stats.complete` is part of the assertion);
//! * the same scenario with [`Config::weaken_notify_all`], which makes
//!   every `notify_all` behave as `notify_one` — the historical PR-1
//!   lost-wakeup — asserted to **deadlock**.  That second half is what
//!   proves the suite would catch the regression if someone reintroduced
//!   it: weakening the wakeups makes these tests fail loudly, not pass
//!   quietly.
//!
//! Timed pops: the model has no wall clock (`wait_timeout_clean` never
//! times out under loom), so scenarios pass an hour-long timeout and rely
//! on pushes/`close()` to release waiters — exactly the paths under test.
//!
//! Without `--cfg loom` this file compiles to an empty test binary.
#![cfg(loom)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex as StdMutex};
use std::time::Duration;

use synergy::cluster::QueueBank;
use synergy::mm::job::{ClassMask, Classed, JobClass};
use synergy::pipeline::Mailbox;
use synergy::util::model::{explore, spawn, Config, Stats};

/// Far beyond any model run: timeout-popping APIs must be released by a
/// notification, never by the deadline check around the wait.
const FOREVER: Duration = Duration::from_secs(3600);

fn weakened(base: Config) -> Config {
    Config {
        weaken_notify_all: true,
        ..base
    }
}

fn assert_sound(stats: Stats) {
    assert!(
        stats.complete,
        "exploration must exhaust the schedule space: {stats:?}"
    );
    assert_eq!(stats.deadlocks, 0, "found a deadlocking schedule: {stats:?}");
}

fn assert_guards(stats: Stats) {
    assert!(
        stats.deadlocks > 0,
        "weakened notify_all must deadlock somewhere — the suite would \
         not catch the notify_one regression: {stats:?}"
    );
}

// ------------------------------------------------------------- mailbox

/// `Mailbox::close()` with two consumers parked on `not_empty`: the
/// broadcast must release both (drain-then-None contract).
fn mailbox_close_consumers(cfg: Config) -> Stats {
    explore(cfg, || {
        let mb: Arc<Mailbox<u32>> = Arc::new(Mailbox::new(1));
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let mb = Arc::clone(&mb);
                spawn(move || {
                    assert_eq!(mb.recv(), None, "nothing was sent before close");
                })
            })
            .collect();
        mb.close();
        for c in consumers {
            c.join();
        }
    })
}

#[test]
fn mailbox_close_releases_every_consumer() {
    assert_sound(mailbox_close_consumers(Config::default()));
}

#[test]
fn mailbox_close_consumer_broadcast_guards_notify_one() {
    assert_guards(mailbox_close_consumers(weakened(Config::default())));
}

/// `Mailbox::close()` with two producers parked on `not_full` (mailbox
/// pre-filled to capacity): the broadcast must release both, and both
/// sends must report the close.
fn mailbox_close_producers(cfg: Config) -> Stats {
    explore(cfg, || {
        let mb: Arc<Mailbox<u32>> = Arc::new(Mailbox::new(1));
        assert!(mb.send(99), "pre-fill to capacity");
        let producers: Vec<_> = (1..=2)
            .map(|v| {
                let mb = Arc::clone(&mb);
                spawn(move || {
                    assert!(!mb.send(v), "no consumer pops; send must observe close");
                })
            })
            .collect();
        mb.close();
        for p in producers {
            p.join();
        }
    })
}

#[test]
fn mailbox_close_releases_blocked_producers() {
    assert_sound(mailbox_close_producers(Config::default()));
}

#[test]
fn mailbox_close_producer_broadcast_guards_notify_one() {
    assert_guards(mailbox_close_producers(weakened(Config::default())));
}

/// The headline regression: 2 producers, 2 consumers, capacity-1 mailbox.
/// Producers block on `not_full`, consumers drain until `None`, close
/// arrives while consumers are re-parked — every wake-up path in `send`/
/// `recv`/`close` gets exercised.  Conservation is checked per schedule:
/// both sent items are received exactly once.
///
/// Preemption bound 1 keeps the space at ~64k schedules (measured); all
/// blocking-point switches and wake choices are free, so every lost-wakeup
/// shape is still reachable (the weakened twin below proves it).
fn mailbox_2p2c(cfg: Config) -> Stats {
    explore(cfg, || {
        let mb: Arc<Mailbox<u64>> = Arc::new(Mailbox::new(1));
        let got: Arc<StdMutex<Vec<u64>>> = Arc::new(StdMutex::new(Vec::new()));
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let mb = Arc::clone(&mb);
                let got = Arc::clone(&got);
                spawn(move || {
                    while let Some(v) = mb.recv() {
                        // Plain std mutex: result collection is not part
                        // of the checked state space (tasks are already
                        // serialized), so it adds no schedule points.
                        got.lock().unwrap().push(v);
                    }
                })
            })
            .collect();
        let producers: Vec<_> = (1..=2u64)
            .map(|v| {
                let mb = Arc::clone(&mb);
                spawn(move || {
                    assert!(mb.send(v), "queue closes only after producers join");
                })
            })
            .collect();
        for p in producers {
            p.join();
        }
        mb.close();
        for c in consumers {
            c.join();
        }
        let mut got = got.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2], "each sent item received exactly once");
    })
}

fn bound1() -> Config {
    Config {
        preemption_bound: 1,
        max_executions: 1_000_000,
        ..Config::default()
    }
}

#[test]
fn mailbox_2p2c_at_capacity_conserves_and_never_deadlocks() {
    assert_sound(mailbox_2p2c(bound1()));
}

#[test]
fn mailbox_2p2c_close_broadcast_guards_notify_one() {
    assert_guards(mailbox_2p2c(weakened(bound1())));
}

// ----------------------------------------------------------- queue bank

#[derive(Debug, PartialEq, Eq)]
struct CItem(u64, usize);

impl Classed for CItem {
    fn class_index(&self) -> usize {
        self.1
    }
}

fn conv_mask() -> ClassMask {
    ClassMask::of(&[JobClass::ConvTile])
}

fn fc_mask() -> ClassMask {
    ClassMask::of(&[JobClass::FcGemm])
}

/// The masked-member lost wakeup: two delegates with disjoint capability
/// masks park on the bank's single condvar; a push of a CONV item must not
/// hand its only notification to the FC-only member (which cannot take the
/// item and re-parks, stranding it) — this is why `QueueBank::push`
/// broadcasts.  Close must then release the FC member that never had
/// anything to pop.
fn queue_bank_masked(cfg: Config) -> Stats {
    explore(cfg, || {
        let qb: Arc<QueueBank<CItem>> = Arc::new(QueueBank::new());
        let taken = Arc::new(AtomicUsize::new(0));
        let conv = {
            let qb = Arc::clone(&qb);
            let taken = Arc::clone(&taken);
            spawn(move || loop {
                match qb.pop_any_timeout(conv_mask(), FOREVER) {
                    Ok(Some(item)) => {
                        assert_eq!(item, CItem(7, 0));
                        taken.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(None) => return,
                    Err(()) => panic!("model runs never time out"),
                }
            })
        };
        let fc = {
            let qb = Arc::clone(&qb);
            spawn(move || loop {
                match qb.pop_any_timeout(fc_mask(), FOREVER) {
                    Ok(Some(item)) => panic!("FC member popped {item:?} outside its mask"),
                    Ok(None) => return,
                    Err(()) => panic!("model runs never time out"),
                }
            })
        };
        assert!(qb.push(CItem(7, 0)));
        qb.close();
        conv.join();
        fc.join();
        assert_eq!(taken.load(Ordering::Relaxed), 1, "the CONV item must land");
    })
}

#[test]
fn queue_bank_masked_wakeup_never_strands_an_item() {
    assert_sound(queue_bank_masked(Config::default()));
}

#[test]
fn queue_bank_push_broadcast_guards_notify_one() {
    assert_guards(queue_bank_masked(weakened(Config::default())));
}

/// Pop/steal conservation under contention: a popping delegate and a
/// stealing thief race over three queued items; every schedule must hand
/// each item to exactly one of them.
#[test]
fn queue_bank_pop_steal_conserves() {
    let stats = explore(Config::default(), || {
        let qb: Arc<QueueBank<CItem>> = Arc::new(QueueBank::new());
        for v in 1..=3 {
            assert!(qb.push(CItem(v, 0)));
        }
        let popped: Arc<StdMutex<Vec<u64>>> = Arc::new(StdMutex::new(Vec::new()));
        let consumer = {
            let qb = Arc::clone(&qb);
            let popped = Arc::clone(&popped);
            spawn(move || loop {
                match qb.pop_any_timeout(conv_mask(), FOREVER) {
                    Ok(Some(CItem(v, _))) => popped.lock().unwrap().push(v),
                    Ok(None) => return,
                    Err(()) => panic!("model runs never time out"),
                }
            })
        };
        let stolen: Arc<StdMutex<Vec<u64>>> = Arc::new(StdMutex::new(Vec::new()));
        let thief = {
            let qb = Arc::clone(&qb);
            let stolen = Arc::clone(&stolen);
            spawn(move || {
                let grabbed = qb.steal_where(2, conv_mask());
                stolen.lock().unwrap().extend(grabbed.into_iter().map(|i| i.0));
            })
        };
        thief.join();
        qb.close();
        consumer.join();
        let mut all = popped.lock().unwrap().clone();
        all.extend(stolen.lock().unwrap().iter().copied());
        all.sort_unstable();
        assert_eq!(all, vec![1, 2, 3], "pop + steal must partition the items");
    });
    assert_sound(stats);
}
