//! Remote-shard acceptance suite — the registry's first real plug-in,
//! proven end to end (ISSUE 5):
//!
//! * **(a)** jobs executed on the remote end are **bit-identical** to
//!   local pool execution across the model zoo (duplex transport, the
//!   remote member as the only CONV/fused-FC-capable member, so every
//!   such job demonstrably crosses the wire);
//! * **(b)** killing the transport **mid-batch loses zero jobs** — the
//!   dying delegate requeues its run and local members drain it, with the
//!   blocking dispatch APIs completing correctly (a lost job would hang
//!   them, a dropped reply would panic them);
//! * **(c)** over **real TCP** against a [`ShardServer`] hosting a second
//!   `DelegatePool`, the default routing (shipping-cost penalty + idle
//!   stealing, no test-side special cases) sends CONV-tile and fused
//!   batched-FC work to the remote member, visible in
//!   `PoolReport::per_accel_by_class` and balanced against the shard
//!   pool's own ledger;
//! * **(d–f)** the **operand-cache protocol** (ISSUE 7): the uncached
//!   per-tile frame stays exactly the packed fetch set (the baseline the
//!   cache is measured against), a layer's planes PUT once and every tile
//!   after that ships a size-pinned descriptor-only frame with
//!   bit-identical cold/warm results and exactly one re-ship per repack,
//!   and steady-state conv2 traffic to a warm shard clears the ≥3×
//!   wire-byte acceptance bar on the exact `wire_bytes()` ledger;
//! * **(g)** **fleet health**: killing one shard of a two-shard fleet
//!   mid-run loses zero jobs, and the dead member is evicted from routing
//!   (its ledger row freezes — no further route attempts) while the
//!   surviving shard keeps serving.
//! * **(h)** **operand-cache edge cases** (ISSUE 8): the LRU eviction
//!   floor never drops the two most-recent entries no matter how far a
//!   single fetch-set pair overshoots capacity, a shard that never
//!   retains operands exhausts the bounded miss→re-PUT→retry cycle as a
//!   clean error (not a livelock), and the shared-cache hit/miss/evict
//!   counters balance exactly against two clients' ledgers under
//!   interleaved connections.
//!
//! Everything is constructed through the public registry API — `rt/`
//! knows nothing about shards.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::anyhow;
use synergy::accel::remote::{
    duplex_pair, remote_class_mask, serve_shard_transport, serve_transport, shard_backend_name,
    wire, RemoteShard, ShardCache, ShardTransport, REMOTE_OVERHEAD_KSTEPS,
};
use synergy::accel::{
    register_config_shards, AccelClass, Accelerator, BackendRegistry, BackendSpec, NativeGemm,
};
use synergy::config::{zoo, ClusterCfg, HwConfig};
use synergy::mm::job::{gather_results, jobs_for_gemm, ClassMask, Job, JobClass};
use synergy::mm::TileGrid;
use synergy::nn::Network;
use synergy::rt::{ComputeMode, DelegatePool, Dispatcher, PoolOptions, PoolRouter};
use synergy::runtime::default_artifacts_dir;
use synergy::sched::static_map;
use synergy::serve::ShardServer;
use synergy::util::rng::XorShift64Star;

/// A one-cluster, one-NEON hardware config (the all-local baseline pool).
fn local_hw() -> HwConfig {
    let mut hw = HwConfig::default_zc702();
    hw.clusters = vec![ClusterCfg {
        name: "local".into(),
        neon: 1,
        big_neon: 0,
        remote: Vec::new(),
        pes: Vec::new(),
    }];
    hw
}

/// Split topology for (a): cluster 0 holds one local member restricted to
/// FC/im2col, cluster 1 holds one remote member (CONV + fused FC) over an
/// in-process duplex transport serviced by `shard_thread` — every
/// CONV-tile and fused-FC job MUST cross the transport.
fn split_remote_pool() -> (DelegatePool, JoinHandle<u64>) {
    let addr = "duplex:0";
    let mut hw = HwConfig::default_zc702();
    hw.clusters = vec![
        ClusterCfg {
            name: "local".into(),
            neon: 1,
            big_neon: 0,
            remote: Vec::new(),
            pes: Vec::new(),
        },
        ClusterCfg {
            name: "shard".into(),
            neon: 0,
            big_neon: 0,
            remote: vec![addr.into()],
            pes: Vec::new(),
        },
    ];

    let (client, mut server) = duplex_pair();
    let shard_thread = std::thread::Builder::new()
        .name("duplex-shard".into())
        .spawn(move || serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap())
        .expect("spawn duplex shard");

    // Out-of-tree registry, public API only: a restricted local "neon"
    // (FC + im2col) and the shard entry holding the pre-connected duplex
    // client for its single delegate.
    let mut registry = BackendRegistry::new();
    registry.register(
        BackendSpec::new("neon", || Ok(Box::new(NativeGemm) as Box<dyn Accelerator>))
            .caps(ClassMask::of(&[JobClass::FcGemm, JobClass::Im2col])),
    );
    let slot = Mutex::new(Some(client));
    let name = shard_backend_name(addr);
    let id = name.clone();
    registry.register(
        BackendSpec::new(&name, move || {
            let transport = slot
                .lock()
                .unwrap()
                .take()
                .ok_or_else(|| anyhow!("duplex transport already taken"))?;
            Ok(Box::new(RemoteShard::new(
                id.clone(),
                remote_class_mask(),
                REMOTE_OVERHEAD_KSTEPS,
                Box::new(transport),
            )) as Box<dyn Accelerator>)
        })
        .caps(remote_class_mask())
        .overhead_ksteps(REMOTE_OVERHEAD_KSTEPS),
    );

    let mut options = PoolOptions::new(hw, ComputeMode::Native, false);
    options.registry = Some(Arc::new(registry));
    let pool = DelegatePool::start(&options).expect("start split pool");
    (pool, shard_thread)
}

/// Blocking un-hinted GEMM through the generic dispatch surface: pack
/// once, reserve ids, fan the tile jobs out, gather C.
fn run_gemm(dispatcher: &Dispatcher, grid: TileGrid, a: Arc<Vec<f32>>, b: Arc<Vec<f32>>) -> Vec<f32> {
    let mut next_id = dispatcher.reserve_job_ids(grid.num_jobs() as u64);
    let jobs = jobs_for_gemm(0, 0, grid, a, b, &mut next_id);
    gather_results(grid, &dispatcher.execute_jobs(jobs))
}

fn forward_through(pool: &DelegatePool, net: &Network, frame: u64) -> synergy::tensor::Tensor {
    let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
    let router = PoolRouter::new(net, pool.dispatcher(), &assignment);
    net.forward_with(&net.make_input(frame), &router.frame(frame))
}

/// (a) Bit-identical remote execution across the model zoo, with the
/// per-accelerator ledger proving which member ran what.
#[test]
fn remote_execution_is_bit_identical_across_the_zoo() {
    for (i, name) in zoo::ZOO.iter().enumerate() {
        let net = Network::new(zoo::load(name).unwrap(), 32).unwrap();
        let frame = i as u64;

        // Baseline: the same forward through an all-local pool.
        let local_pool =
            DelegatePool::start(&PoolOptions::new(local_hw(), ComputeMode::Native, false))
                .unwrap();
        let y_local = forward_through(&local_pool, &net, frame);
        local_pool.shutdown().unwrap();

        // Remote-backed pool: CONV tiles can only execute on the shard.
        let (pool, shard_thread) = split_remote_pool();
        let y_remote = forward_through(&pool, &net, frame);
        assert_eq!(
            y_remote.data(),
            y_local.data(),
            "{name}: remote execution diverged bitwise"
        );

        let accels = pool.accels();
        let report = pool.shutdown().unwrap();
        shard_thread.join().unwrap();
        assert_eq!(report.inline_fallbacks, 0, "{name}");
        assert_eq!(report.delegate_failures, 0, "{name}");
        let profile = net.pool_job_profile();
        let remote = accels
            .iter()
            .find(|a| matches!(a.class, AccelClass::Remote { .. }))
            .expect("remote member");
        let by_class = &report.per_accel_by_class[remote.id];
        assert_eq!(
            by_class[JobClass::ConvTile.index()],
            profile[JobClass::ConvTile.index()] as u64,
            "{name}: remote member must execute every CONV tile"
        );
        assert_eq!(by_class[JobClass::FcGemm.index()], 0, "{name}");
        assert_eq!(by_class[JobClass::Im2col.index()], 0, "{name}");
        // The restricted local member served everything else.
        let local = &report.per_accel_by_class[0];
        assert_eq!(local[JobClass::ConvTile.index()], 0, "{name}");
        assert_eq!(
            local[JobClass::FcGemm.index()],
            profile[JobClass::FcGemm.index()] as u64,
            "{name}"
        );
    }
}

/// (a, fused) Batched forwards fuse FC layers into `FcGemmBatch` jobs that
/// also cross the wire bit-identically.
#[test]
fn remote_fused_fc_batches_are_bit_identical() {
    for name in ["mpcnn", "mnist"] {
        let net = Network::new(zoo::load(name).unwrap(), 32).unwrap();
        let xs: Vec<_> = (0..3u64).map(|f| net.make_input(f)).collect();

        let local_pool =
            DelegatePool::start(&PoolOptions::new(local_hw(), ComputeMode::Native, false))
                .unwrap();
        let assignment = static_map::assign(&net.conv_infos(), local_pool.clusters());
        let router = PoolRouter::new(&net, local_pool.dispatcher(), &assignment);
        let ys_local = net.forward_batch_with(&xs, &router.frame(0));
        local_pool.shutdown().unwrap();

        let (pool, shard_thread) = split_remote_pool();
        let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
        let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);
        let ys_remote = net.forward_batch_with(&xs, &router.frame(0));
        for (j, (a, b)) in ys_local.iter().zip(&ys_remote).enumerate() {
            assert_eq!(a.data(), b.data(), "{name}: batched request {j} diverged");
        }

        let accels = pool.accels();
        let report = pool.shutdown().unwrap();
        shard_thread.join().unwrap();
        let remote = accels
            .iter()
            .find(|a| matches!(a.class, AccelClass::Remote { .. }))
            .expect("remote member");
        assert_eq!(
            report.per_accel_by_class[remote.id][JobClass::FcGemmBatch.index()],
            net.fc_layer_count() as u64,
            "{name}: every fused FC job must execute remotely"
        );
        assert_eq!(report.fused_fc_rows, (net.fc_layer_count() * 3) as u64);
        assert_eq!(report.inline_fallbacks, 0);
    }
}

/// (b) Killing the transport mid-batch loses zero jobs: the dying remote
/// delegate requeues its drained run, the local member finishes it, and
/// the blocking dispatch call returns the correct result.
#[test]
fn transport_kill_mid_batch_loses_zero_jobs() {
    let addr = "duplex:1";
    let mut hw = HwConfig::default_zc702();
    // ONE mixed cluster: the local NEON shares the bank the dying remote
    // member requeues into.
    hw.clusters = vec![ClusterCfg {
        name: "mixed".into(),
        neon: 1,
        big_neon: 0,
        remote: vec![addr.into()],
        pes: Vec::new(),
    }];

    let (client, mut server) = duplex_pair();
    let shard_thread = std::thread::Builder::new()
        .name("killable-shard".into())
        .spawn(move || {
            let mut served = 0usize;
            // Serve exactly 3 jobs, then sever the link "mid-batch".
            let result = serve_transport(&mut server, move |job| {
                if served == 3 {
                    anyhow::bail!("injected transport kill");
                }
                served += 1;
                Ok(job.execute_native())
            });
            assert!(result.is_err(), "shard must end by injected kill");
        })
        .expect("spawn killable shard");

    let mut registry = BackendRegistry::new();
    registry.register(BackendSpec::new("neon", || {
        Ok(Box::new(NativeGemm) as Box<dyn Accelerator>)
    }));
    let slot = Mutex::new(Some(client));
    let name = shard_backend_name(addr);
    let id = name.clone();
    registry.register(
        BackendSpec::new(&name, move || {
            let transport = slot
                .lock()
                .unwrap()
                .take()
                .ok_or_else(|| anyhow!("duplex transport already taken"))?;
            Ok(Box::new(RemoteShard::new(
                id.clone(),
                remote_class_mask(),
                REMOTE_OVERHEAD_KSTEPS,
                Box::new(transport),
            )) as Box<dyn Accelerator>)
        })
        .caps(remote_class_mask())
        .overhead_ksteps(REMOTE_OVERHEAD_KSTEPS),
    );

    let mut options = PoolOptions::new(hw, ComputeMode::Native, false);
    // Mid-batch: the remote delegate drains several jobs per visit, so the
    // kill strands a multi-job run that must be requeued whole.
    options.drain_extra = 3;
    options.registry = Some(Arc::new(registry));
    let pool = DelegatePool::start(&options).unwrap();
    let dispatcher = pool.dispatcher();

    // A 24-tile GEMM: the shard dies partway through; a lost job would
    // hang this blocking call forever (the test harness timeout catches
    // that), a dropped reply channel would panic it.
    let grid = TileGrid::new(192, 1024, 128, 32);
    let a = Arc::new(XorShift64Star::new(1).fill_f32(192 * 1024, 1.0));
    let b = Arc::new(XorShift64Star::new(2).fill_f32(1024 * 128, 1.0));
    let c = run_gemm(&dispatcher, grid, Arc::clone(&a), Arc::clone(&b));
    let want = synergy::mm::gemm::gemm_blocked(
        &synergy::tensor::Tensor::from_vec(&[192, 1024], (*a).clone()),
        &synergy::tensor::Tensor::from_vec(&[1024, 128], (*b).clone()),
    );
    let got = synergy::tensor::Tensor::from_vec(&[192, 128], c);
    assert!(
        want.allclose(&got, 1e-3, 1e-3),
        "result corrupted after transport kill: {}",
        want.max_abs_diff(&got)
    );

    // The pool keeps serving after the death — fused FC included.
    let w = Arc::new(XorShift64Star::new(3).fill_f32(16 * 24, 1.0));
    let xb = Arc::new(XorShift64Star::new(4).fill_f32(24 * 2, 1.0));
    let id = dispatcher.reserve_job_ids(1);
    let y = dispatcher
        .execute_job(Job::fc_batch(id, 0, 0, 16, 24, 2, Arc::clone(&w), Arc::clone(&xb), 32))
        .data;
    let mut want_y = vec![0.0f32; 16 * 2];
    synergy::mm::gemm::gemm_blocked_into(&w, &xb, &mut want_y, 16, 24, 2);
    assert_eq!(y, want_y);

    shard_thread.join().unwrap();
    let accels = pool.accels();
    let report = pool.shutdown().unwrap();
    // Zero loss, fully accounted: every job executed exactly once.
    assert_eq!(
        report.per_class_jobs[JobClass::ConvTile.index()],
        grid.num_jobs() as u64
    );
    assert_eq!(report.per_class_jobs[JobClass::FcGemmBatch.index()], 1);
    assert_eq!(report.delegate_failures, 1, "the shard delegate must die");
    assert!(report.requeued_jobs >= 1, "the stranded run must requeue");
    assert_eq!(report.inline_fallbacks, 0);
    // The dying delegate also evicts its routing link: the member leaves
    // placement instead of being rediscovered via requeue.
    assert_eq!(report.evicted_members, 1);
    // The shard executed exactly the 3 jobs it served before the kill.
    let remote = accels
        .iter()
        .find(|a| matches!(a.class, AccelClass::Remote { .. }))
        .expect("remote member");
    assert_eq!(report.per_accel_jobs[remote.id], 3);
    // Conservation: shard + local = everything, nothing double-counted.
    assert_eq!(
        report.jobs_executed,
        grid.num_jobs() as u64 + 1,
        "jobs lost or executed twice after the kill"
    );
}

/// (c) Real TCP, default routing: a `ShardServer` hosting a second pool
/// joins the default ZC702 topology as a third cluster, and the stock
/// dispatcher/thief (shipping-cost penalty + idle stealing) offload
/// CONV-tile and fused batched-FC work onto it under backlog — proven by
/// the per-accelerator ledger on the client and balanced against the
/// shard pool's own report.
#[test]
fn tcp_shard_executes_conv_and_fused_fc_under_default_routing() {
    // Remote end: its own two-NEON pool behind a TCP listener.
    let mut shard_hw = HwConfig::default_zc702();
    shard_hw.clusters = vec![ClusterCfg {
        name: "shard-pool".into(),
        neon: 2,
        big_neon: 0,
        remote: Vec::new(),
        pes: Vec::new(),
    }];
    let shard = ShardServer::start(
        "127.0.0.1:0",
        &PoolOptions::new(shard_hw, ComputeMode::Native, false),
    )
    .unwrap();
    let addr = shard.addr().to_string();

    // Client end: the default ZC702 platform plus one remote member, with
    // the default registry + the config-named shard registration — the
    // exact config-driven deployment path.
    let mut hw = HwConfig::default_zc702();
    hw.clusters.push(ClusterCfg {
        name: "offload".into(),
        neon: 0,
        big_neon: 0,
        remote: vec![addr.clone()],
        pes: Vec::new(),
    });
    let mut registry =
        BackendRegistry::with_defaults(default_artifacts_dir(), hw.big_neon_threads);
    register_config_shards(&mut registry, &hw);
    let mut options = PoolOptions::new(hw, ComputeMode::Native, true);
    options.registry = Some(Arc::new(registry));
    let pool = Arc::new(DelegatePool::start(&options).unwrap());
    let remote_id = pool
        .accels()
        .iter()
        .find(|a| matches!(a.class, AccelClass::Remote { .. }))
        .expect("remote member")
        .id;

    // Load rounds: concurrent un-hinted CONV GEMMs + fused FC batches.
    // Small jobs stay local while queues are shallow (the shipping
    // penalty); the backlog each round builds tips large work onto the
    // shard — keep pushing until the ledger shows the remote member
    // executed BOTH classes.
    let grid = TileGrid::new(128, 512, 128, 32);
    let a = Arc::new(XorShift64Star::new(5).fill_f32(128 * 512, 1.0));
    let b = Arc::new(XorShift64Star::new(6).fill_f32(512 * 128, 1.0));
    let want_c = synergy::mm::gemm::gemm_blocked(
        &synergy::tensor::Tensor::from_vec(&[128, 512], (*a).clone()),
        &synergy::tensor::Tensor::from_vec(&[512, 128], (*b).clone()),
    );
    let w = Arc::new(XorShift64Star::new(7).fill_f32(64 * 128, 1.0));
    let xb = Arc::new(XorShift64Star::new(8).fill_f32(128 * 8, 1.0));
    let mut want_y = vec![0.0f32; 64 * 8];
    synergy::mm::gemm::gemm_blocked_into(&w, &xb, &mut want_y, 64, 128, 8);

    let diverged = Arc::new(AtomicBool::new(false));
    let mut round = 0usize;
    loop {
        round += 1;
        assert!(
            round <= 150,
            "default routing never offloaded both classes to the shard: {:?}",
            pool.snapshot().per_accel_by_class[remote_id]
        );
        let mut workers = Vec::new();
        for t in 0..3usize {
            let pool = Arc::clone(&pool);
            let (a, b) = (Arc::clone(&a), Arc::clone(&b));
            let (w, xb) = (Arc::clone(&w), Arc::clone(&xb));
            let want_c = want_c.clone();
            let want_y = want_y.clone();
            let diverged = Arc::clone(&diverged);
            workers.push(std::thread::spawn(move || {
                let dispatcher = pool.dispatcher();
                let c = run_gemm(&dispatcher, grid, a, b);
                let got = synergy::tensor::Tensor::from_vec(&[128, 128], c);
                if !want_c.allclose(&got, 1e-3, 1e-3) {
                    diverged.store(true, Ordering::Relaxed);
                }
                let id = dispatcher.reserve_job_ids(1);
                let y = dispatcher
                    .execute_job(Job::fc_batch(id, t, t as u64, 64, 128, 8, w, xb, 32))
                    .data;
                if y != want_y {
                    diverged.store(true, Ordering::Relaxed);
                }
            }));
        }
        for h in workers {
            h.join().unwrap();
        }
        assert!(!diverged.load(Ordering::Relaxed), "offloaded work diverged");
        let ledger = pool.snapshot().per_accel_by_class[remote_id];
        if ledger[JobClass::ConvTile.index()] > 0 && ledger[JobClass::FcGemmBatch.index()] > 0
        {
            break;
        }
    }

    // Client first, shard second (connection threads exit on client
    // disconnect) — the deployment shutdown order.
    let pool = Arc::try_unwrap(pool).unwrap_or_else(|_| panic!("pool still shared"));
    let report = pool.shutdown().unwrap();
    assert_eq!(report.inline_fallbacks, 0);
    assert_eq!(report.delegate_failures, 0);
    let remote_row = &report.per_accel_by_class[remote_id];
    assert!(remote_row[JobClass::ConvTile.index()] > 0);
    assert!(remote_row[JobClass::FcGemmBatch.index()] > 0);
    assert_eq!(remote_row[JobClass::FcGemm.index()], 0);
    assert_eq!(remote_row[JobClass::Im2col.index()], 0);

    let shard_report = shard.shutdown().unwrap();
    // The two ledgers balance: every job the client's remote member
    // completed was executed by the shard pool, class by class.
    assert_eq!(
        shard_report.per_class_jobs[JobClass::ConvTile.index()],
        remote_row[JobClass::ConvTile.index()]
    );
    assert_eq!(
        shard_report.per_class_jobs[JobClass::FcGemmBatch.index()],
        remote_row[JobClass::FcGemmBatch.index()]
    );
    assert_eq!(shard_report.inline_fallbacks, 0);
}

/// (d) Wire-bytes regression (operand-plane redesign): with the operand
/// cache off, a shipped CONV tile's request frame is *exactly* its packed
/// fetch set — one tag byte, the descriptor, and two length-prefixed
/// `(K·TS·TS)`-element panel runs serialized straight from the job's
/// operand views.  The client ledger counts precisely the request +
/// result frame bytes, so any future double-buffering through an
/// intermediate `Vec` before the codec (or any re-widening of the wire
/// payload back to layer matrices) fails these equalities.  This is the
/// per-tile baseline the cache tests below measure against.
#[test]
fn conv_tile_wire_bytes_equal_the_packed_fetch_set() {
    let (client, mut server) = duplex_pair();
    let shard_thread = std::thread::Builder::new()
        .name("byte-counted-shard".into())
        .spawn(move || serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap())
        .expect("spawn byte-counted shard");
    let mut shard = RemoteShard::over_duplex("remote:bytes", client).with_operand_cache(false);

    // Ragged edges on every side: 40×50×60 at ts=32.
    let grid = TileGrid::new(40, 50, 60, 32);
    let a = Arc::new(XorShift64Star::new(11).fill_f32(40 * 50, 1.0));
    let b = Arc::new(XorShift64Star::new(12).fill_f32(50 * 60, 1.0));
    let mut id = 0;
    let jobs = jobs_for_gemm(0, 0, grid, a, b, &mut id);
    assert_eq!(jobs.len(), grid.num_jobs());

    let mut expected_ledger = 0u64;
    for job in &jobs {
        let request = wire::encode_job(job);
        let panel = job.desc.k_tiles() * grid.ts * grid.ts;
        assert_eq!(
            request.len(),
            1 + wire::DESC_BYTES + 2 * (8 + 4 * panel),
            "tile ({}, {}): frame is not exactly the packed fetch set",
            job.desc.t1,
            job.desc.t2
        );
        let result = shard.execute(job).unwrap();
        assert_eq!(result.data, job.execute_native().data);
        expected_ledger += (request.len() + wire::encode_result(&result).len()) as u64;
        assert_eq!(
            shard.wire_bytes(),
            expected_ledger,
            "client wire ledger drifted from the frames actually exchanged"
        );
    }
    drop(shard); // hang up → the serve loop exits cleanly
    let served = shard_thread.join().unwrap();
    assert_eq!(served, grid.num_jobs() as u64);
}

/// (e) Cache protocol: a layer's two packed planes PUT exactly once, every
/// tile ships a size-pinned 137-byte descriptor frame, warm-hit results
/// are bit-identical to the cold round, and a repack (fresh plane
/// allocations → fresh operand keys for the same layer slots) costs
/// exactly one DROP + one re-PUT per plane.
#[test]
fn cache_protocol_descriptor_frames_and_single_reship_on_repack() {
    let (client, mut server) = duplex_pair();
    let shard_thread = std::thread::Builder::new()
        .name("cached-shard".into())
        .spawn(move || serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap())
        .expect("spawn cached shard");
    let mut shard = RemoteShard::over_duplex("remote:cached", client);

    let grid = TileGrid::new(40, 50, 60, 32);
    let a = Arc::new(XorShift64Star::new(21).fill_f32(40 * 50, 1.0));
    let b = Arc::new(XorShift64Star::new(22).fill_f32(50 * 60, 1.0));
    let mut id = 0;
    let jobs = jobs_for_gemm(0, 0, grid, Arc::clone(&a), Arc::clone(&b), &mut id);

    // Cold pass: PUT-on-first-use, then descriptors.
    let cold: Vec<_> = jobs.iter().map(|j| shard.execute(j).unwrap()).collect();
    let stats = shard.cache_stats();
    assert_eq!(stats.puts, 2, "one PUT per packed plane, never per tile");
    assert_eq!(stats.refs, jobs.len() as u64);
    assert_eq!(stats.drops, 0);
    assert_eq!(stats.misses, 0);

    // Warm pass over the SAME jobs: the ledger may grow by exactly one
    // descriptor frame + one result frame per tile — nothing else.
    let before = shard.wire_bytes();
    let warm: Vec<_> = jobs.iter().map(|j| shard.execute(j).unwrap()).collect();
    let result_bytes: u64 = warm
        .iter()
        .map(|r| wire::encode_result(r).len() as u64)
        .sum();
    assert_eq!(
        shard.wire_bytes() - before,
        jobs.len() as u64 * wire::REF_FRAME_BYTES as u64 + result_bytes,
        "a warm tile must cost exactly one descriptor-only frame"
    );
    assert_eq!(shard.cache_stats().puts, 2, "warm tiles never re-PUT");
    for ((c, w), job) in cold.iter().zip(&warm).zip(&jobs) {
        assert_eq!(c.data, w.data, "cold-miss vs warm-hit diverged");
        assert_eq!(c.data, job.execute_native().data, "cached path diverged from native");
    }

    // Pack-generation bump: repacking the same operands mints new plane
    // buffers, hence new keys for the same (layer, role) slots — the
    // client invalidates the stale keys and re-ships each plane once.
    let mut id2 = 100;
    let jobs2 = jobs_for_gemm(0, 0, grid, a, b, &mut id2);
    for job in &jobs2 {
        assert_eq!(shard.execute(job).unwrap().data, job.execute_native().data);
    }
    let stats = shard.cache_stats();
    assert_eq!(stats.drops, 2, "one invalidation frame per repacked plane");
    assert_eq!(stats.puts, 4, "exactly one re-ship per repacked plane");
    assert_eq!(stats.misses, 0);

    drop(shard);
    let served = shard_thread.join().unwrap();
    assert_eq!(served, (2 * jobs.len() + jobs2.len()) as u64);
}

/// (f) Acceptance (ISSUE 7): steady-state CONV traffic to a warm shard
/// ships ≥3× fewer bytes than the per-tile-fetch-set baseline on the
/// conv2-shaped grid, proven by the exact `wire_bytes()` ledgers of two
/// shards fed the identical tile stream — with bitwise-identical results.
#[test]
fn warm_shard_ships_3x_fewer_bytes_on_conv2_grid() {
    // conv2 of the paper's MNIST-class network at ts = 32: the 800-deep
    // reduction gives each plane 25 k-tiles of reuse across 14 tile jobs.
    let grid = TileGrid::new(64, 800, 196, 32);
    let a = Arc::new(XorShift64Star::new(31).fill_f32(64 * 800, 1.0));
    let b = Arc::new(XorShift64Star::new(32).fill_f32(800 * 196, 1.0));
    let mut id = 0;
    let jobs = jobs_for_gemm(0, 0, grid, Arc::clone(&a), Arc::clone(&b), &mut id);
    assert_eq!(jobs.len(), 14);

    // Baseline shard: the full packed fetch set in every request frame.
    let (client, mut server) = duplex_pair();
    let base_thread = std::thread::Builder::new()
        .name("baseline-shard".into())
        .spawn(move || serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap())
        .expect("spawn baseline shard");
    let mut base = RemoteShard::over_duplex("remote:base", client).with_operand_cache(false);
    let base_results: Vec<_> = jobs.iter().map(|j| base.execute(j).unwrap()).collect();
    let base_bytes = base.wire_bytes();
    drop(base);
    base_thread.join().unwrap();

    // Cached shard: one cold round (PUTs + descriptors), then the same
    // tile stream again — the steady state a serving pool lives in.
    let (client, mut server) = duplex_pair();
    let cached_thread = std::thread::Builder::new()
        .name("warm-shard".into())
        .spawn(move || serve_transport(&mut server, |job| Ok(job.execute_native())).unwrap())
        .expect("spawn warm shard");
    let mut cached = RemoteShard::over_duplex("remote:warm", client);
    let cold_results: Vec<_> = jobs.iter().map(|j| cached.execute(j).unwrap()).collect();
    let cold_bytes = cached.wire_bytes();
    let warm_results: Vec<_> = jobs.iter().map(|j| cached.execute(j).unwrap()).collect();
    let warm_bytes = cached.wire_bytes() - cold_bytes;
    drop(cached);
    cached_thread.join().unwrap();

    for ((br, cr), wr) in base_results.iter().zip(&cold_results).zip(&warm_results) {
        assert_eq!(br.data, cr.data, "cached cold round diverged from baseline");
        assert_eq!(br.data, wr.data, "warm round diverged from baseline");
    }
    // The steady-state ledger is exact: one descriptor frame + one result
    // frame per tile, nothing else on the wire.
    let result_bytes: u64 = base_results
        .iter()
        .map(|r| wire::encode_result(r).len() as u64)
        .sum();
    assert_eq!(
        warm_bytes,
        14 * wire::REF_FRAME_BYTES as u64 + result_bytes,
        "warm round shipped more than descriptors + results"
    );
    // Even the cold round (planes PUT once) undercuts per-tile shipping…
    assert!(
        cold_bytes < base_bytes,
        "cold cached round {cold_bytes} B vs baseline {base_bytes} B"
    );
    // …and the steady state clears the ≥3× acceptance bar with room (the
    // actual ratio on this grid is ≈55×; 3× also holds on request bytes
    // alone for the cold round).
    assert!(
        base_bytes >= 3 * warm_bytes,
        "baseline {base_bytes} B is not ≥3× the warm round's {warm_bytes} B"
    );
}

/// (g) Fleet health: two remote shards; one dies mid-run.  Zero jobs are
/// lost (the requeued run drains on the mixed cluster's local member),
/// the dead member is **evicted from routing** — its per-accelerator
/// ledger row freezes and its link leaves the cluster's alive set — and
/// the surviving shard keeps serving hinted rounds afterwards.
#[test]
fn killing_one_fleet_shard_loses_nothing_and_evicts_it_from_routing() {
    let addr_a = "duplex:fleet-a";
    let addr_b = "duplex:fleet-b";
    let mut hw = HwConfig::default_zc702();
    hw.clusters = vec![
        // The doomed shard shares a bank with an all-class NEON so its
        // requeued run drains deterministically (no thief involved).
        ClusterCfg {
            name: "mixed".into(),
            neon: 1,
            big_neon: 0,
            remote: vec![addr_b.into()],
            pes: Vec::new(),
        },
        ClusterCfg {
            name: "fleet-a".into(),
            neon: 0,
            big_neon: 0,
            remote: vec![addr_a.into()],
            pes: Vec::new(),
        },
    ];

    // Shard A serves until its peer hangs up; shard B serves exactly 2
    // jobs, then severs the link mid-run.
    let (client_a, mut server_a) = duplex_pair();
    let healthy = std::thread::Builder::new()
        .name("fleet-a".into())
        .spawn(move || serve_transport(&mut server_a, |job| Ok(job.execute_native())).unwrap())
        .expect("spawn healthy shard");
    let (client_b, mut server_b) = duplex_pair();
    let doomed = std::thread::Builder::new()
        .name("fleet-b".into())
        .spawn(move || {
            let mut served = 0usize;
            let result = serve_transport(&mut server_b, move |job| {
                if served == 2 {
                    anyhow::bail!("injected shard death");
                }
                served += 1;
                Ok(job.execute_native())
            });
            assert!(result.is_err(), "doomed shard must end by injected death");
        })
        .expect("spawn doomed shard");

    let mut registry = BackendRegistry::new();
    registry.register(BackendSpec::new("neon", || {
        Ok(Box::new(NativeGemm) as Box<dyn Accelerator>)
    }));
    for (addr, client) in [(addr_a, client_a), (addr_b, client_b)] {
        let slot = Mutex::new(Some(client));
        let name = shard_backend_name(addr);
        let id = name.clone();
        registry.register(
            BackendSpec::new(&name, move || {
                let transport = slot
                    .lock()
                    .unwrap()
                    .take()
                    .ok_or_else(|| anyhow!("duplex transport already taken"))?;
                Ok(Box::new(RemoteShard::new(
                    id.clone(),
                    remote_class_mask(),
                    REMOTE_OVERHEAD_KSTEPS,
                    Box::new(transport),
                )) as Box<dyn Accelerator>)
            })
            .caps(remote_class_mask())
            .overhead_ksteps(REMOTE_OVERHEAD_KSTEPS),
        );
    }

    let mut options = PoolOptions::new(hw, ComputeMode::Native, false);
    // Mid-run: the doomed delegate drains several jobs per pop, so the
    // death strands a multi-job run that must requeue whole.
    options.drain_extra = 3;
    options.registry = Some(Arc::new(registry));
    let pool = DelegatePool::start(&options).unwrap();
    let dispatcher = pool.dispatcher();
    let accels = pool.accels();
    let id_of = |want: &str| {
        accels
            .iter()
            .find(|a| matches!(&a.class, AccelClass::Remote { addr } if addr.as_str() == want))
            .expect("remote member")
            .id
    };
    let (id_a, id_b) = (id_of(addr_a), id_of(addr_b));

    // One 24-tile GEMM reused for every round.
    let grid = TileGrid::new(192, 1024, 128, 32);
    let a = Arc::new(XorShift64Star::new(41).fill_f32(192 * 1024, 1.0));
    let b = Arc::new(XorShift64Star::new(42).fill_f32(1024 * 128, 1.0));
    let want = synergy::mm::gemm::gemm_blocked(
        &synergy::tensor::Tensor::from_vec(&[192, 1024], (*a).clone()),
        &synergy::tensor::Tensor::from_vec(&[1024, 128], (*b).clone()),
    );
    let run_round = |hint: Option<usize>| {
        let mut next = dispatcher.reserve_job_ids(grid.num_jobs() as u64);
        let jobs: Vec<Job> =
            jobs_for_gemm(0, 0, grid, Arc::clone(&a), Arc::clone(&b), &mut next)
                .into_iter()
                .map(|j| j.placed(hint))
                .collect();
        let c = gather_results(grid, &dispatcher.execute_jobs(jobs));
        let got = synergy::tensor::Tensor::from_vec(&[192, 128], c);
        assert!(
            want.allclose(&got, 1e-3, 1e-3),
            "round diverged by {}",
            want.max_abs_diff(&got)
        );
    };

    // Round 1, hinted at the mixed cluster: B dies partway through; a lost
    // job would hang the blocking call, a dropped reply would panic it.
    run_round(Some(0));
    doomed.join().unwrap();

    // Eviction: the dead link left the mixed cluster's alive set, the
    // failure and the eviction are both counted, and B's ledger row shows
    // exactly the 2 jobs it served before dying.
    let snap = pool.snapshot();
    assert_eq!(snap.delegate_failures, 1, "the doomed delegate must die");
    assert_eq!(snap.evicted_members, 1, "the dead shard must leave routing");
    assert_eq!(snap.per_accel_jobs[id_b], 2);
    let alive = pool.routes()[0]
        .members()
        .iter()
        .filter(|m| m.link.is_alive())
        .count();
    assert_eq!(alive, 1, "only the local NEON survives in the mixed cluster");

    // Round 2, hinted at the fleet cluster: the surviving shard serves the
    // whole round (no thief in this topology), proving the fleet still
    // routes remote work after the eviction.
    run_round(Some(1));
    // Round 3, hinted back at the mixed cluster: the local NEON absorbs
    // everything — NO further jobs reach the evicted member.
    run_round(Some(0));

    let report = pool.shutdown().unwrap();
    assert_eq!(healthy.join().unwrap(), grid.num_jobs() as u64);
    assert_eq!(
        report.jobs_executed,
        3 * grid.num_jobs() as u64,
        "jobs lost or executed twice across the fleet kill"
    );
    assert_eq!(report.per_accel_jobs[id_b], 2, "the evicted member's ledger row froze");
    assert_eq!(
        report.per_accel_jobs[id_a],
        grid.num_jobs() as u64,
        "the surviving shard must serve the whole post-kill round"
    );
    assert!(report.requeued_jobs >= 1, "the stranded run must requeue");
    assert_eq!(report.inline_fallbacks, 0);
    assert_eq!(report.delegate_failures, 1);
    assert_eq!(report.evicted_members, 1);
}

/// (h) Eviction floor: `ShardCache::put` never drops below the **two**
/// most-recent entries, no matter how far each buffer overshoots the
/// nominal capacity — the fetch-set *pair* one CONV tile references must
/// always be co-resident or the miss→re-PUT→retry cycle would thrash
/// forever on a cache smaller than one working set.
#[test]
fn shard_cache_eviction_floor_never_drops_the_mru_pair() {
    // Capacity far below a single buffer: every put is over capacity.
    let cache = ShardCache::with_capacity_elems(10);
    cache.put((7, 0), vec![0.5; 64]);
    cache.put((7, 1), vec![1.5; 64]);
    for round in 2..6u64 {
        cache.put((7, round), vec![round as f32; 64]);
        let stats = cache.stats();
        assert_eq!(stats.entries, 2, "round {round}: the floor is the MRU pair");
        assert_eq!(stats.elems, 2 * 64, "round {round}");
    }
    // Each over-capacity put evicted exactly one LRU peer, and the
    // survivors are exactly the two most recently put keys.
    assert_eq!(cache.stats().evictions, 4);
    assert!(cache.get((7, 4)).is_some(), "second-most-recent key evicted");
    assert!(cache.get((7, 5)).is_some(), "just-put key evicted");
    for old in 0..4u64 {
        assert!(cache.get((7, old)).is_none(), "stale key {old} survived the floor");
    }

    // Recency follows *touches*, not insertion order: bumping the older
    // entry with a get flips which peer the next put evicts.
    let cache = ShardCache::with_capacity_elems(10);
    cache.put((9, 1), vec![1.0; 64]);
    cache.put((9, 2), vec![2.0; 64]);
    assert!(cache.get((9, 1)).is_some()); // recency bump
    cache.put((9, 3), vec![3.0; 64]);
    assert!(cache.get((9, 2)).is_none(), "untouched peer must be the victim");
    assert!(cache.get((9, 1)).is_some());
    assert!(cache.get((9, 3)).is_some());
    assert_eq!(cache.stats().evictions, 1);

    // Refreshing a resident key replaces its payload in place: no
    // eviction, and the element ledger tracks the new size.
    cache.put((9, 1), vec![4.0; 32]);
    let stats = cache.stats();
    assert_eq!(stats.entries, 2);
    assert_eq!(stats.elems, 64 + 32);
    assert_eq!(stats.evictions, 1);
    assert_eq!(cache.get((9, 1)).unwrap().len(), 32);
}

/// (h) Retry cap: a shard that answers every descriptor REF with
/// `CACHE_MISS` (it accepts PUTs but never retains them) must exhaust the
/// client's bounded re-ship cycle — exactly three REF attempts, both keys
/// re-PUT after each — and surface as a clean "kept missing" error
/// instead of livelocking the delegate thread.
#[test]
fn amnesiac_shard_exhausts_the_miss_retry_cap_as_an_error() {
    let (client, mut server) = duplex_pair();
    let fake = std::thread::Builder::new()
        .name("amnesiac-shard".into())
        .spawn(move || {
            let (mut puts, mut refs) = (0u64, 0u64);
            loop {
                let frame = match server.recv() {
                    Ok(frame) => frame,
                    Err(_) => return (puts, refs), // client hung up
                };
                match wire::decode_shard_frame(&frame).unwrap() {
                    wire::ShardFrame::OperandPut { .. } => puts += 1,
                    wire::ShardFrame::OperandDrop { .. } => {}
                    wire::ShardFrame::ConvTileRef { desc, a, b } => {
                        refs += 1;
                        let miss = wire::encode_cache_miss(&desc, &[a.key, b.key]);
                        if server.send(&miss).is_err() {
                            return (puts, refs);
                        }
                    }
                    _ => panic!("amnesiac shard got a non-cache frame"),
                }
            }
        })
        .expect("spawn amnesiac shard");

    // One CONV tile (32×64×32 at ts=32 is a 1×1 grid) through the cached
    // path against the shard that forgets everything.
    let mut shard = RemoteShard::over_duplex("remote:amnesiac", client);
    let grid = TileGrid::new(32, 64, 32, 32);
    let a = Arc::new(XorShift64Star::new(51).fill_f32(32 * 64, 1.0));
    let b = Arc::new(XorShift64Star::new(52).fill_f32(64 * 32, 1.0));
    let mut id = 0;
    let jobs = jobs_for_gemm(0, 0, grid, a, b, &mut id);
    assert_eq!(jobs.len(), 1);

    let err = shard
        .execute(&jobs[0])
        .expect_err("a shard that never retains operands must fail the job");
    let msg = format!("{err:#}");
    assert!(msg.contains("kept missing"), "unexpected error: {msg}");

    // The cap is visible in the client ledger: 3 REF attempts, a miss for
    // each, the initial plane pair plus both keys re-shipped per round.
    let stats = shard.cache_stats();
    assert_eq!(stats.refs, 3, "retry cap must be three descriptor attempts");
    assert_eq!(stats.misses, 3);
    assert_eq!(stats.puts, 2 + 3 * 2, "{stats:?}");
    assert_eq!(stats.drops, 0);

    // …and in the fake shard's own frame counts.
    drop(shard);
    let (puts, refs) = fake.join().unwrap();
    assert_eq!(refs, 3);
    assert_eq!(puts, 8);
}

/// (h) Shared-cache accounting balance: two client connections against ONE
/// `ShardCache` (the `ShardServer` topology), interleaved tile-for-tile.
/// Resident planes never miss; pushing past capacity evicts and the
/// affected client recovers transparently and bit-identically; and the
/// server-side hit/miss/evict counters balance *exactly* against both
/// clients' REF/PUT ledgers.
#[test]
fn shared_cache_stats_balance_across_interleaved_connections() {
    // Sized to exactly four packed planes — two layers' fetch sets.
    const PLANE: usize = 2 * 2 * 32 * 32; // tiles × k_tiles × ts² on this grid
    let cache = ShardCache::with_capacity_elems(4 * PLANE);
    let (client_a, server_a) = duplex_pair();
    let (client_b, server_b) = duplex_pair();
    let cache_for = |mut server: Box<dyn ShardTransport>, name: &str| {
        let cache = Arc::clone(&cache);
        std::thread::Builder::new()
            .name(name.into())
            .spawn(move || {
                serve_shard_transport(&mut *server, &cache, 0.0, |job| Ok(job.execute_native()))
                    .unwrap()
            })
            .expect("spawn shared-cache shard")
    };
    let thread_a = cache_for(Box::new(server_a), "ilv-a");
    let thread_b = cache_for(Box::new(server_b), "ilv-b");

    let grid = TileGrid::new(40, 50, 60, 32);
    let mut id = 0;
    let mut mk_layer = |layer: usize, seed: u64| {
        let a = Arc::new(XorShift64Star::new(seed).fill_f32(40 * 50, 1.0));
        let b = Arc::new(XorShift64Star::new(seed + 1).fill_f32(50 * 60, 1.0));
        jobs_for_gemm(layer, 1, grid, a, b, &mut id)
    };
    let layer0 = mk_layer(0, 61);
    let layer1 = mk_layer(1, 63);
    let layer2 = mk_layer(2, 65);
    assert_eq!(layer0.len(), 4, "40×50×60 at ts=32 is a 2×2 tile grid");

    let mut shard_a = RemoteShard::over_duplex("remote:ilv-a", client_a);
    let mut shard_b = RemoteShard::over_duplex("remote:ilv-b", client_b);
    let check = |shard: &mut RemoteShard, job: &Job| {
        let got = shard.execute(job).unwrap();
        assert_eq!(got.data, job.execute_native().data, "job {}", job.desc.job_id);
    };

    // Cold round + warm round, strictly interleaved across connections:
    // all four planes stay resident, so nothing may miss or evict.
    for round in 0..2 {
        for i in 0..4 {
            check(&mut shard_a, &layer0[i]);
            check(&mut shard_b, &layer1[i]);
        }
        let stats = cache.stats();
        assert_eq!(stats.entries, 4, "round {round}");
        assert_eq!(stats.elems, 4 * PLANE, "round {round}");
        assert_eq!(stats.misses, 0, "resident planes must never miss");
        assert_eq!(stats.evictions, 0, "round {round}");
    }
    // 16 REF frames so far, exactly two lookups each — all hits.
    assert_eq!(cache.stats().hits, 32);

    // Connection A brings in a third layer: two more planes push the one
    // shared cache over capacity and evict the least-recently-touched.
    for job in &layer2 {
        check(&mut shard_a, job);
    }
    let mid = cache.stats();
    assert!(mid.evictions >= 2, "{mid:?}");
    assert!(mid.elems <= 4 * PLANE, "{mid:?}");

    // Both clients re-run their first layer.  Their `shipped` sets still
    // claim the keys, but the shared cache evicted some — the
    // miss→re-PUT→retry cycle recovers transparently, bit-identically.
    for i in 0..4 {
        check(&mut shard_a, &layer0[i]);
        check(&mut shard_b, &layer1[i]);
    }

    let (sa, sb) = (shard_a.cache_stats(), shard_b.cache_stats());
    let server = cache.stats();
    // Exact balance #1: every REF frame the server handled did exactly two
    // lookups — across BOTH connections against the one cache.
    assert_eq!(
        server.hits + server.misses,
        2 * (sa.refs + sb.refs),
        "lookup ledger drifted: server {server:?}, clients {sa:?} / {sb:?}"
    );
    // Exact balance #2: every failed server lookup named one missing key
    // in a CACHE_MISS reply, and the owning client re-PUT exactly that key
    // — so total PUTs are the six cold planes plus one per server miss.
    assert_eq!(
        sa.puts + sb.puts,
        6 + server.misses,
        "re-ship ledger drifted: server {server:?}, clients {sa:?} / {sb:?}"
    );
    // The over-capacity re-run must actually have exercised recovery, and
    // each client CACHE_MISS reply carried one or two missing keys.
    let client_misses = sa.misses + sb.misses;
    assert!(client_misses >= 1, "eviction recovery never ran: {sa:?} / {sb:?}");
    assert!(
        server.misses >= client_misses && server.misses <= 2 * client_misses,
        "miss ledgers inconsistent: server {server:?}, clients {sa:?} / {sb:?}"
    );
    assert_eq!(sa.drops + sb.drops, 0, "no pack bump happened");

    // Served counts: misses don't execute; every request completed once.
    drop(shard_a);
    drop(shard_b);
    assert_eq!(thread_a.join().unwrap(), 16);
    assert_eq!(thread_b.join().unwrap(), 12);
}
