//! Unified-pool integration: every class of matrix work — CONV-tile
//! GEMMs, FC GEMMs, and im2col lowering — must be dispatched to (and
//! counted by) the shared heterogeneous accelerator pool, FC layers
//! included (they previously ran inline on the pipeline thread).  Steal
//! accounting must stay consistent across the job classes.

use std::sync::Arc;

use synergy::config::zoo;
use synergy::mm::JobClass;
use synergy::nn::Network;
use synergy::rt::driver::run_stream;
use synergy::rt::RtOptions;
use synergy::tensor::Tensor;

fn mk_net(name: &str) -> Arc<Network> {
    Arc::new(Network::new(zoo::load(name).unwrap(), 32).unwrap())
}

/// End-to-end: FC-layer GEMMs are executed by pool delegates, not inline —
/// the per-class and per-accel counters prove it, and outputs still match
/// the reference forward.
#[test]
fn fc_layers_execute_on_the_pool_not_inline() {
    let net = mk_net("mnist"); // 2 CONV + 2 FC layers
    let frames: Vec<(u64, Tensor)> = (0..4).map(|f| (f, net.make_input(f))).collect();
    let n_frames = frames.len();
    let report = run_stream(Arc::clone(&net), RtOptions::default(), frames).unwrap();

    for (frame_id, out) in &report.outputs {
        let want = net.forward_reference(&net.make_input(*frame_id));
        assert!(
            out.allclose(&want, 1e-4, 1e-5),
            "frame {frame_id}: {}",
            out.max_abs_diff(&want)
        );
    }

    let profile = net.pool_job_profile();
    // mnist has two FC layers → two FC jobs per frame, counted by class.
    assert_eq!(profile[JobClass::FcGemm.index()], 2);
    assert_eq!(
        report.per_class_jobs[JobClass::FcGemm.index()],
        (2 * n_frames) as u64
    );
    // One im2col job per CONV layer per frame.
    assert_eq!(
        report.per_class_jobs[JobClass::Im2col.index()],
        (profile[JobClass::Im2col.index()] * n_frames) as u64
    );
    // Class counters and per-accelerator counters both balance the total.
    assert_eq!(
        report.per_class_jobs.iter().sum::<u64>(),
        report.jobs_executed
    );
    assert_eq!(
        report.per_accel_jobs.iter().sum::<u64>(),
        report.jobs_executed
    );
    // Every job of every class went through the pool — never inline.
    assert_eq!(
        report.jobs_executed,
        (profile.iter().sum::<usize>() * n_frames) as u64
    );
    assert_eq!(report.inline_fallbacks, 0);
}

/// The serving fused-FC acceptance: a B-request micro-batch driven
/// through the server executes exactly ONE `FcGemmBatch` job per FC
/// layer (never one per request), with zero inline fallbacks on the
/// default ZC702 topology and reference-exact outputs.
#[test]
fn serving_batch_emits_one_fused_fc_job_per_fc_layer() {
    use std::time::Duration;
    use synergy::serve::{Request, Server, ServeOptions};

    let net = mk_net("mnist"); // 2 CONV + 2 FC layers
    let batch = 4usize;
    let mut options = ServeOptions::default();
    options.batch.max_batch = batch;
    // A long window: the batch dispatches on reaching max_batch, so all
    // B requests ride one micro-batch deterministically.
    options.batch.window = Duration::from_secs(5);
    options.admission_depth = 64;
    let server = Server::start(vec![Arc::clone(&net)], options).unwrap();
    for seq in 0..batch as u64 {
        let input = net.make_input(seq);
        assert!(server.submit(Request::new(0, seq, 0, input)), "shed?");
    }
    while server.completed() < batch as u64 {
        std::thread::sleep(Duration::from_millis(2));
    }
    let (stats, responses) = server.shutdown().unwrap();

    assert_eq!(responses.len(), batch);
    for r in &responses {
        assert_eq!(r.batch_size, batch, "request rode a smaller batch");
        let want = net.forward_reference(&net.make_input(r.frame));
        assert!(
            r.output.allclose(&want, 1e-4, 1e-5),
            "frame {}: {}",
            r.frame,
            r.output.max_abs_diff(&want)
        );
    }

    // Exactly one fused job per FC layer for the whole batch — the
    // fused-vs-unfused split is visible per class.
    assert_eq!(
        stats.per_class_jobs[JobClass::FcGemmBatch.index()],
        net.fc_layer_count() as u64
    );
    assert_eq!(stats.per_class_jobs[JobClass::FcGemm.index()], 0);
    assert_eq!(stats.fused_fc_rows, (net.fc_layer_count() * batch) as u64);
    // The CONV front-end still runs per request.
    let profile = net.pool_job_profile_batched(batch);
    assert_eq!(
        stats.per_class_jobs[JobClass::ConvTile.index()],
        profile[JobClass::ConvTile.index()] as u64
    );
    assert_eq!(
        stats.per_class_jobs[JobClass::Im2col.index()],
        profile[JobClass::Im2col.index()] as u64
    );
    assert_eq!(stats.inline_fallbacks, 0, "default ZC702 must never fall back");
    assert_eq!(stats.expired, 0);
    assert_eq!(stats.shed, 0);
}

/// Steal accounting stays consistent across backend classes: the per-class
/// stolen counters sum to the total, and no class is stolen that was never
/// dispatched.
#[test]
fn steal_accounting_consistent_across_classes() {
    let net = mk_net("cifar_darknet");
    let frames: Vec<(u64, Tensor)> = (0..6).map(|f| (f, net.make_input(f))).collect();
    let report = run_stream(Arc::clone(&net), RtOptions::default(), frames).unwrap();

    // Work stealing is on by default; whatever moved must balance.
    let rt_report = report;
    let stolen_sum: u64 = {
        // per-class stolen counters live on the pool report; the driver
        // surfaces totals — rerun through the pool API for class detail.
        rt_report.jobs_stolen
    };
    assert!(rt_report.steal_attempts >= 1, "thief never woke up");
    assert!(stolen_sum <= rt_report.jobs_executed);

    // Class-level detail via a direct pool run.
    use synergy::config::HwConfig;
    use synergy::rt::{ComputeMode, DelegatePool, PoolOptions, PoolRouter};
    use synergy::sched::static_map;
    let options = PoolOptions::new(HwConfig::default_zc702(), ComputeMode::Native, true);
    let pool = DelegatePool::start(&options).unwrap();
    let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
    let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);
    for f in 0..4u64 {
        let exec = router.frame(f);
        let y = net.forward_with(&net.make_input(f), &exec);
        assert_eq!(y.shape(), &[10]);
    }
    let report = pool.shutdown().unwrap();
    assert_eq!(
        report.stolen_by_class.iter().sum::<u64>(),
        report.jobs_stolen,
        "per-class stolen counters must balance the total"
    );
    for class in JobClass::ALL {
        assert!(
            report.stolen_by_class[class.index()] <= report.per_class_jobs[class.index()],
            "{}: stolen more than dispatched",
            class.label()
        );
    }
    assert_eq!(
        report.per_class_jobs.iter().sum::<u64>(),
        report.jobs_executed
    );
    // Dispatch accounting: everything handed to the banks was executed
    // (drained before shutdown), and nothing ran inline.
    assert_eq!(report.dispatched_by_class, report.per_class_jobs);
    assert_eq!(report.inline_fallbacks, 0);
    // Per-member class counters fold to the per-class totals.
    let mut folded = [0u64; synergy::mm::JobClass::COUNT];
    for accel in &report.per_accel_by_class {
        for (acc, n) in folded.iter_mut().zip(accel) {
            *acc += n;
        }
    }
    assert_eq!(folded, report.per_class_jobs);
}
