//! End-to-end serving integration: multiple client streams over multiple
//! networks through admission, micro-batching, the per-net pipelines, and
//! the shared accelerator pool — outputs must match the reference forward
//! and the request accounting must balance exactly.

use std::sync::Arc;
use std::time::Duration;

use synergy::config::zoo;
use synergy::nn::Network;
use synergy::serve::{Request, RequestStream, ServeOptions, Server};

fn mk_net(name: &str) -> Arc<Network> {
    Arc::new(Network::new(zoo::load(name).unwrap(), 32).unwrap())
}

#[test]
fn two_streams_two_networks_zero_loss_and_correct() {
    let nets = vec![mk_net("mpcnn"), mk_net("mnist")];
    let mut options = ServeOptions::default();
    options.batch.max_batch = 4;
    options.batch.window = Duration::from_millis(4);
    options.admission_depth = 256;
    let server = Arc::new(Server::start(nets.clone(), options).unwrap());

    let mut clients = Vec::new();
    for stream_id in 0..4usize {
        let net_id = stream_id % nets.len();
        let server = Arc::clone(&server);
        let mut stream =
            RequestStream::new(stream_id, net_id, Arc::clone(&nets[net_id]), 800.0, 8);
        clients.push(std::thread::spawn(move || {
            let mut admitted = 0u64;
            while let Some((gap, req)) = stream.next_arrival() {
                std::thread::sleep(gap);
                if server.submit(req) {
                    admitted += 1;
                }
            }
            admitted
        }));
    }
    let admitted: u64 = clients.into_iter().map(|c| c.join().unwrap()).sum();
    assert_eq!(admitted, 32, "depth 256 must admit everything");

    let server = match Arc::try_unwrap(server) {
        Ok(s) => s,
        Err(_) => panic!("server still shared"),
    };
    let (stats, responses) = server.shutdown().unwrap();

    // Zero loss: everything admitted completed (no deadlines set).
    assert_eq!(stats.completed, admitted);
    assert_eq!(stats.expired, 0);
    assert_eq!(responses.len() as u64, admitted);

    // Numerics: every response equals the reference forward for its frame.
    for resp in &responses {
        let net = &nets[resp.net_id];
        let want = net.forward_reference(&net.make_input(resp.frame));
        assert!(
            resp.output.allclose(&want, 1e-4, 1e-5),
            "stream {} seq {}: {}",
            resp.stream_id,
            resp.seq,
            resp.output.max_abs_diff(&want)
        );
    }

    // Per-stream FIFO: responses of one stream keep their sequence order
    // (batches preserve admission order inside one network's pipeline).
    for sid in 0..4usize {
        let seqs: Vec<u64> = responses
            .iter()
            .filter(|r| r.stream_id == sid)
            .map(|r| r.seq)
            .collect();
        let mut sorted = seqs.clone();
        sorted.sort_unstable();
        assert_eq!(seqs, sorted, "stream {sid} reordered");
    }

    // All matrix work went through the shared pool: the CONV front-end
    // (tiles + im2col) per request, FC layers as ONE fused FcGemmBatch
    // job per micro-batch per layer — never inline, never per-request.
    use synergy::mm::JobClass;
    let conv_front: u64 = responses
        .iter()
        .map(|r| {
            let p = nets[r.net_id].pool_job_profile();
            (p[JobClass::ConvTile.index()] + p[JobClass::Im2col.index()]) as u64
        })
        .sum();
    let fused_jobs = stats.per_class_jobs[JobClass::FcGemmBatch.index()];
    assert_eq!(stats.jobs_executed, conv_front + fused_jobs);
    assert_eq!(
        stats.per_class_jobs[JobClass::FcGemm.index()],
        0,
        "per-request FC jobs must not exist on the fused serving path"
    );
    // Every request's FC work is covered by fused rows, exactly once per
    // FC layer it passed through.
    let expected_fc_rows: u64 = responses
        .iter()
        .map(|r| nets[r.net_id].fc_layer_count() as u64)
        .sum();
    assert!(expected_fc_rows > 0, "zoo models must have FC layers");
    assert_eq!(stats.fused_fc_rows, expected_fc_rows);
    // Fusion only ever shrinks the job count: one job per batch per FC
    // layer, bounded by the per-request count.
    assert!(fused_jobs >= 1 && fused_jobs <= expected_fc_rows);
    assert_eq!(stats.inline_fallbacks, 0, "serving must never compute inline");
}

#[test]
fn overload_sheds_instead_of_blocking() {
    let nets = vec![mk_net("mpcnn"), mk_net("mnist")];
    let mut options = ServeOptions::default();
    // Tiny admission queue + slow window: floods must shed, not hang.
    options.admission_depth = 2;
    options.batch.max_batch = 2;
    options.batch.window = Duration::from_millis(1);
    let server = Server::start(nets.clone(), options).unwrap();
    let mut admitted = 0u64;
    let mut shed = 0u64;
    // Burst far beyond the depth without pacing.
    for seq in 0..64u64 {
        let req = Request::new(0, seq, 0, nets[0].make_input(seq));
        if server.submit(req) {
            admitted += 1;
        } else {
            shed += 1;
        }
    }
    let (stats, responses) = server.shutdown().unwrap();
    assert_eq!(admitted + shed, 64);
    assert!(shed > 0, "a 2-deep queue cannot absorb a 64-burst");
    assert_eq!(stats.shed, shed);
    assert_eq!(stats.completed, admitted);
    assert_eq!(responses.len() as u64, admitted);
}

#[test]
fn per_net_admission_lanes_isolate_overload() {
    let nets = vec![mk_net("mpcnn"), mk_net("mnist")];
    let mut options = ServeOptions::default();
    // Tiny per-lane depth: net 0's flood fills only net 0's lane.
    options.admission_depth = 2;
    options.batch.max_batch = 2;
    options.batch.window = Duration::from_millis(1);
    let server = Server::start(nets.clone(), options).unwrap();
    let mut net0_shed = 0u64;
    for seq in 0..64u64 {
        let req = Request::new(0, seq, 0, nets[0].make_input(seq));
        if !server.submit(req) {
            net0_shed += 1;
        }
    }
    // Net 1's lane has its own depth budget: its trickle is admitted even
    // while net 0 is shedding.
    assert!(net0_shed > 0, "a 2-deep lane cannot absorb a 64-burst");
    for seq in 0..2u64 {
        let req = Request::new(1, seq, 1, nets[1].make_input(seq));
        assert!(
            server.submit(req),
            "net 1 starved by net 0's overload (lane isolation broken)"
        );
    }
    let (stats, responses) = server.shutdown().unwrap();
    assert_eq!(stats.shed, net0_shed);
    // Both net-1 requests completed.
    let net1_done = responses.iter().filter(|r| r.net_id == 1).count();
    assert_eq!(net1_done, 2);
}

#[test]
fn deadline_expiry_is_counted_not_lost() {
    let nets = vec![mk_net("mpcnn"), mk_net("mnist")];
    let mut options = ServeOptions::default();
    options.batch.window = Duration::from_millis(1);
    let server = Server::start(nets.clone(), options).unwrap();
    // A deadline of zero: expired by the time the batcher sees it.
    let req = Request::new(0, 0, 0, nets[0].make_input(0)).with_deadline(Duration::ZERO);
    assert!(server.submit(req));
    // And one serviceable request.
    let req = Request::new(0, 1, 0, nets[0].make_input(1));
    assert!(server.submit(req));
    // Give the batcher time to drain both before shutdown.
    std::thread::sleep(Duration::from_millis(50));
    let (stats, responses) = server.shutdown().unwrap();
    assert_eq!(stats.expired, 1);
    assert_eq!(stats.completed, 1);
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].seq, 1);
}

#[test]
fn batching_observed_under_synchronized_burst() {
    let nets = vec![mk_net("mpcnn"), mk_net("mnist")];
    let mut options = ServeOptions::default();
    options.batch.max_batch = 4;
    // Wide window so the whole burst coalesces deterministically.
    options.batch.window = Duration::from_millis(200);
    options.admission_depth = 64;
    let server = Server::start(nets.clone(), options).unwrap();
    for seq in 0..8u64 {
        let req = Request::new(0, seq, 0, nets[0].make_input(seq));
        assert!(server.submit(req));
    }
    std::thread::sleep(Duration::from_millis(100));
    let (stats, responses) = server.shutdown().unwrap();
    assert_eq!(stats.completed, 8);
    assert!(
        stats.max_batch > 1,
        "an 8-burst into a 200ms window must batch (max {})",
        stats.max_batch
    );
    assert!(responses.iter().any(|r| r.batch_size > 1));
}
