//! Integration: the PJRT-executed AOT artifacts agree with the native Rust
//! compute path — the core L1/L2 ↔ L3 numerical contract.
//!
//! Requires `make artifacts` and the `pjrt` cargo feature (the whole file
//! is compiled out of the default CI build; without artifacts it skips).
#![cfg(feature = "pjrt")]

use std::sync::Arc;

use synergy::config::zoo;
use synergy::mm::tile::{job_mm_native, TileGrid};
use synergy::nn::Network;
use synergy::runtime::{default_artifacts_dir, Manifest, ModelOracle, PeEngine};
use synergy::tensor::Tensor;
use synergy::util::rng::XorShift64Star;

fn artifacts_or_skip() -> Option<std::path::PathBuf> {
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn job_kernel_matches_native_for_all_k() {
    let Some(dir) = artifacts_or_skip() else { return };
    let engine = PeEngine::load(&dir, None).unwrap();
    let ts = engine.tile_size();
    for k in engine.available_ks() {
        let mut rng = XorShift64Star::new(1000 + k as u64);
        let at = rng.fill_f32(k * ts * ts, 2.0);
        let bt = rng.fill_f32(k * ts * ts, 2.0);
        let pjrt = engine.execute_job(&at, &bt, k).unwrap();
        let native = job_mm_native(&at, &bt, k, ts);
        let a = Tensor::from_vec(&[ts, ts], pjrt);
        let b = Tensor::from_vec(&[ts, ts], native);
        assert!(
            a.allclose(&b, 1e-4, 1e-3),
            "k={k}: max diff {}",
            a.max_abs_diff(&b)
        );
    }
}

#[test]
fn job_kernel_pads_smaller_k() {
    // Ask for a K that has no exact kernel: engine must pick the next
    // larger one and zero-pad (paper's border rule applied at K level).
    let Some(dir) = artifacts_or_skip() else { return };
    let engine = PeEngine::load(&dir, None).unwrap();
    let ts = engine.tile_size();
    let ks = engine.available_ks();
    // Find a gap K (e.g. 2 when kernels are 1,3,4,...).
    let k_gap = (1..50).find(|k| !ks.contains(k) && ks.iter().any(|&kk| kk > *k));
    let Some(k) = k_gap else { return };
    let mut rng = XorShift64Star::new(7);
    let at = rng.fill_f32(k * ts * ts, 2.0);
    let bt = rng.fill_f32(k * ts * ts, 2.0);
    let pjrt = engine.execute_job(&at, &bt, k).unwrap();
    let native = job_mm_native(&at, &bt, k, ts);
    let a = Tensor::from_vec(&[ts, ts], pjrt);
    let b = Tensor::from_vec(&[ts, ts], native);
    assert!(a.allclose(&b, 1e-4, 1e-3), "k={k}: {}", a.max_abs_diff(&b));
}

#[test]
fn full_gemm_through_pjrt_jobs_matches_blocked_gemm() {
    let Some(dir) = artifacts_or_skip() else { return };
    let engine = PeEngine::load(&dir, None).unwrap();
    let ts = engine.tile_size();
    // CIFAR conv1-shaped GEMM: (32, 75, 1024) — ragged N.
    let grid = TileGrid::new(32, 75, 256, ts);
    let mut rng = XorShift64Star::new(42);
    let a = Arc::new(rng.fill_f32(grid.m * grid.n, 1.0));
    let b = Arc::new(rng.fill_f32(grid.n * grid.p, 1.0));
    let mut c = vec![0.0f32; grid.m * grid.p];
    for (t1, t2) in grid.tiles() {
        let at = grid.extract_a_tiles(&a, t1);
        let bt = grid.extract_b_tiles(&b, t2);
        let tile = engine.execute_job(&at, &bt, grid.k_tiles()).unwrap();
        grid.scatter_c(&mut c, t1, t2, &tile);
    }
    let want = synergy::mm::gemm::gemm_blocked(
        &Tensor::from_vec(&[grid.m, grid.n], (*a).clone()),
        &Tensor::from_vec(&[grid.n, grid.p], (*b).clone()),
    );
    let got = Tensor::from_vec(&[grid.m, grid.p], c);
    assert!(want.allclose(&got, 1e-4, 1e-3), "{}", want.max_abs_diff(&got));
}

#[test]
fn model_oracle_matches_rust_forward_mpcnn() {
    model_oracle_case("mpcnn", 1e-4);
}

#[test]
fn model_oracle_matches_rust_forward_mnist() {
    model_oracle_case("mnist", 1e-4);
}

#[test]
fn model_oracle_matches_rust_forward_cifar_full_with_batchnorm() {
    model_oracle_case("cifar_full", 1e-4);
}

/// The decisive end-to-end check: Rust-initialized weights + Rust forward
/// vs the AOT JAX model executed through PJRT.  Exercises the identical-
/// weights contract (util::rng ↔ python prng) and every layer kind.
fn model_oracle_case(name: &str, tol: f32) {
    let Some(dir) = artifacts_or_skip() else { return };
    let oracle = ModelOracle::load(&dir, name).unwrap();
    let net = Network::new(zoo::load(name).unwrap(), 32).unwrap();

    // Manifest and Rust must agree on the parameter schedule.
    assert_eq!(oracle.meta.params.len(), net.params.len(), "{name}");
    for (meta, param) in oracle.meta.params.iter().zip(&net.params) {
        assert_eq!(meta.layer, param.layer, "{name}");
        assert_eq!(meta.name, param.name, "{name}");
        assert_eq!(meta.len(), param.len(), "{name}");
    }

    let x = net.make_input(0);
    let params: Vec<&[f32]> = net.params.iter().map(|p| p.data()).collect();
    let pjrt = oracle.run(x.data(), &params).unwrap();
    let rust = net.forward_reference(&x);

    let a = Tensor::from_vec(&[pjrt.len()], pjrt);
    assert!(
        a.allclose(&rust, tol, tol),
        "{name}: max diff {}",
        a.max_abs_diff(&rust)
    );
}

#[test]
fn manifest_mops_matches_rust_accounting() {
    let Some(dir) = artifacts_or_skip() else { return };
    let man = Manifest::load(&dir).unwrap();
    for meta in &man.models {
        let net = Network::new(zoo::load(&meta.name).unwrap(), 32).unwrap();
        let got = net.mops();
        assert!(
            (got - meta.mops).abs() < 0.01 * meta.mops.max(1.0),
            "{}: rust {} vs manifest {}",
            meta.name,
            got,
            meta.mops
        );
    }
}
