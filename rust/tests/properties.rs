//! Property-based tests (mini-proptest harness) on the coordinator's core
//! invariants: tiling covers the iteration space exactly, jobs execute
//! exactly once, stealing neither duplicates nor drops, queues preserve
//! per-producer FIFO order, and the simulator conserves work.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use synergy::accel::{Accelerator, BackendRegistry, BackendSpec, NativeGemm};
use synergy::cluster::{JobQueue, QueueBank};
use synergy::config::{zoo, ClusterCfg, HwConfig};
use synergy::mm::gemm::gemm_naive;
use synergy::mm::job::{gather_results, jobs_for_gemm, ClassMask, Classed, Job, JobClass};
use synergy::mm::tile::{tiled_gemm, TileGrid};
use synergy::nn::Network;
use synergy::pipeline::Mailbox;
use synergy::rt::{ComputeMode, DelegatePool, PoolOptions, PoolRouter};
use synergy::sched::{static_map, worksteal::choose_victim, worksteal::steal_amount};
use synergy::sim::{simulate, SimSpec};
use synergy::tensor::Tensor;
use synergy::util::proptest::{check, Gen};

#[test]
fn prop_tiling_covers_iteration_space_exactly_once() {
    check("tiling-coverage", 40, |g: &mut Gen| {
        let m = g.usize_in(1, 90);
        let n = g.usize_in(1, 90);
        let p = g.usize_in(1, 90);
        let ts = *g.choose(&[8usize, 16, 32]);
        let grid = TileGrid::new(m, n, p, ts);
        // every output element covered by exactly one job tile
        let mut covered = vec![0u8; m * p];
        for (t1, t2) in grid.tiles() {
            for r in (t1 * ts)..((t1 + 1) * ts).min(m) {
                for c in (t2 * ts)..((t2 + 1) * ts).min(p) {
                    covered[r * p + c] += 1;
                }
            }
        }
        assert!(covered.iter().all(|&c| c == 1), "m={m} n={n} p={p} ts={ts}");
    });
}

#[test]
fn prop_tiled_gemm_equals_naive_any_shape() {
    check("tiled-gemm-correct", 25, |g: &mut Gen| {
        let m = g.usize_in(1, 70);
        let n = g.usize_in(1, 70);
        let p = g.usize_in(1, 70);
        let a = Tensor::from_vec(&[m, n], g.vec_f32(m * n));
        let b = Tensor::from_vec(&[n, p], g.vec_f32(n * p));
        let want = gemm_naive(&a, &b);
        let got = tiled_gemm(&a, &b, 32);
        assert!(
            want.allclose(&got, 1e-3, 1e-3),
            "({m},{n},{p}): {}",
            want.max_abs_diff(&got)
        );
    });
}

#[test]
fn prop_jobs_reassemble_gemm() {
    check("jobs-reassemble", 20, |g: &mut Gen| {
        let m = g.usize_in(1, 64);
        let n = g.usize_in(1, 64);
        let p = g.usize_in(1, 64);
        let grid = TileGrid::new(m, n, p, 32);
        let av = g.vec_f32(m * n);
        let bv = g.vec_f32(n * p);
        let mut id = 0;
        let jobs = jobs_for_gemm(0, 0, grid, Arc::new(av.clone()), Arc::new(bv.clone()), &mut id);
        // execute in a random order (scheduling must not matter)
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        for i in (1..order.len()).rev() {
            let j = g.usize_in(0, i);
            order.swap(i, j);
        }
        let results: Vec<_> = order.iter().map(|&i| jobs[i].execute_native()).collect();
        let c = gather_results(grid, &results);
        let want = gemm_naive(
            &Tensor::from_vec(&[m, n], av),
            &Tensor::from_vec(&[n, p], bv),
        );
        let got = Tensor::from_vec(&[m, p], c);
        assert!(want.allclose(&got, 1e-3, 1e-3));
    });
}

#[test]
fn prop_steal_conserves_jobs() {
    check("steal-conserves", 30, |g: &mut Gen| {
        let n_queues = g.usize_in(2, 4);
        let queues: Vec<JobQueue<u64>> = (0..n_queues).map(|_| JobQueue::new()).collect();
        let mut total = 0u64;
        for q in &queues {
            let n = g.usize_in(0, 50);
            for _ in 0..n {
                q.push(total);
                total += 1;
            }
        }
        // random steal storm
        for _ in 0..g.usize_in(1, 20) {
            let from = g.usize_in(0, n_queues - 1);
            let to = g.usize_in(0, n_queues - 1);
            let stolen = queues[from].steal(steal_amount(queues[from].len()));
            queues[to].push_batch(stolen);
        }
        // drain: every job present exactly once
        let mut seen = HashSet::new();
        for q in &queues {
            q.close();
            while let Some(v) = q.pop_blocking() {
                assert!(seen.insert(v), "duplicated job {v}");
            }
        }
        assert_eq!(seen.len() as u64, total, "lost jobs");
    });
}

#[test]
fn prop_choose_victim_never_picks_idle_or_short() {
    check("victim-valid", 100, |g: &mut Gen| {
        let n = g.usize_in(1, 6);
        let lens: Vec<usize> = (0..n).map(|_| g.usize_in(0, 10)).collect();
        let mut idle = HashSet::new();
        for i in 0..n {
            if g.bool() {
                idle.insert(i);
            }
        }
        let min_len = g.usize_in(1, 3);
        if let Some(v) = choose_victim(&lens, &idle, min_len) {
            assert!(!idle.contains(&v));
            assert!(lens[v] >= min_len);
            // it is a maximal candidate
            for (i, &l) in lens.iter().enumerate() {
                if !idle.contains(&i) && l >= min_len {
                    assert!(lens[v] >= l);
                }
            }
        } else {
            // no valid candidate existed
            for (i, &l) in lens.iter().enumerate() {
                assert!(idle.contains(&i) || l < min_len);
            }
        }
    });
}

/// Bank-test item: (id, class index).
struct BItem(u64, usize);
impl Classed for BItem {
    fn class_index(&self) -> usize {
        self.1
    }
}

/// Random mask that is never empty (an empty mask trivially pops nothing).
fn random_mask(g: &mut Gen) -> ClassMask {
    loop {
        let classes: Vec<JobClass> = JobClass::ALL
            .into_iter()
            .filter(|_| g.bool())
            .collect();
        if !classes.is_empty() {
            return ClassMask::of(&classes);
        }
    }
}

#[test]
fn prop_bank_pop_and_steal_respect_masks_without_starvation() {
    check("bank-mask", 40, |g: &mut Gen| {
        let bank: QueueBank<BItem> = QueueBank::new();
        let mut pushed_per_class = [0usize; JobClass::COUNT];
        let mut id = 0u64;
        for class in 0..JobClass::COUNT {
            for _ in 0..g.usize_in(0, 20) {
                bank.push(BItem(id, class));
                pushed_per_class[class] += 1;
                id += 1;
            }
        }
        let mask = random_mask(g);

        // A few steals first: stolen items must match the mask, and
        // sub-queues outside the mask must be untouched.
        let before = bank.class_counts();
        let stolen = bank.steal_where(g.usize_in(0, 10), mask);
        for item in &stolen {
            assert!(mask.supports_index(item.class_index()), "steal leaked class");
        }
        let after_steal = bank.class_counts();
        for i in 0..JobClass::COUNT {
            if !mask.supports_index(i) {
                assert_eq!(before[i], after_steal[i], "class {i} disturbed by steal");
            }
        }

        // Drain through pop_any: only masked classes, bounded bypass — a
        // non-empty eligible sub-queue is served within COUNT pops.
        let mut popped = 0usize;
        loop {
            let counts = bank.class_counts();
            let eligible_nonempty: Vec<usize> = (0..JobClass::COUNT)
                .filter(|&i| mask.supports_index(i) && counts[i] > 0)
                .collect();
            let Some(item) = bank.try_pop_any(mask) else {
                assert!(eligible_nonempty.is_empty(), "pop starved {eligible_nonempty:?}");
                break;
            };
            assert!(mask.supports_index(item.class_index()), "pop leaked class");
            popped += 1;
        }
        // Conservation: masked classes fully drained (popped + stolen),
        // unmasked classes untouched.
        let final_counts = bank.class_counts();
        let mut stolen_per_class = [0usize; JobClass::COUNT];
        for item in &stolen {
            stolen_per_class[item.class_index()] += 1;
        }
        let mut expect_popped = 0usize;
        for i in 0..JobClass::COUNT {
            if mask.supports_index(i) {
                assert_eq!(final_counts[i], 0, "eligible class {i} starved");
                expect_popped += pushed_per_class[i] - stolen_per_class[i];
            } else {
                assert_eq!(final_counts[i], pushed_per_class[i]);
                assert_eq!(stolen_per_class[i], 0);
            }
        }
        assert_eq!(popped, expect_popped, "pop lost or duplicated items");
    });
}

#[test]
fn prop_bank_round_robin_bounded_bypass() {
    check("bank-bypass", 30, |g: &mut Gen| {
        let bank: QueueBank<BItem> = QueueBank::new();
        // A deep backlog on one random class plus one item on another:
        // the singleton must surface within JobClass::COUNT pops of the
        // union mask, despite the deep competitor.
        let deep = g.usize_in(0, JobClass::COUNT - 1);
        let single = (deep + g.usize_in(1, JobClass::COUNT - 1)) % JobClass::COUNT;
        for i in 0..g.usize_in(4, 30) {
            bank.push(BItem(i as u64, deep));
        }
        bank.push(BItem(999, single));
        let mut gap = 0;
        loop {
            let item = bank.try_pop_any(ClassMask::all()).expect("non-empty");
            if item.class_index() == single {
                break;
            }
            gap += 1;
            assert!(gap < JobClass::COUNT, "class {single} bypassed {gap} times");
        }
    });
}

#[test]
fn prop_queue_fifo_per_producer() {
    check("queue-fifo", 20, |g: &mut Gen| {
        let q: JobQueue<(usize, usize)> = JobQueue::new();
        let n_producers = g.usize_in(1, 3);
        let per = g.usize_in(1, 30);
        // interleave pushes from producers in random order
        let mut next = vec![0usize; n_producers];
        while next.iter().any(|&c| c < per) {
            let p = g.usize_in(0, n_producers - 1);
            if next[p] < per {
                q.push((p, next[p]));
                next[p] += 1;
            }
        }
        q.close();
        let mut last = vec![None::<usize>; n_producers];
        while let Some((p, seq)) = q.pop_blocking() {
            if let Some(prev) = last[p] {
                assert!(seq > prev, "producer {p} reordered: {prev} then {seq}");
            }
            last[p] = Some(seq);
        }
    });
}

#[test]
fn prop_mailbox_mpmc_contention_loses_nothing() {
    // Regression stress for the MPMC lost-wakeup: many producers and many
    // consumers hammering a tiny bounded mailbox.  With `notify_one` on the
    // send/recv paths a wake-up could land on a stale waiter and strand the
    // pipeline; with `notify_all` every message must arrive exactly once
    // and all threads must terminate.
    check("mailbox-mpmc", 8, |g: &mut Gen| {
        let capacity = g.usize_in(1, 4);
        let n_producers = g.usize_in(2, 4);
        let n_consumers = g.usize_in(2, 4);
        let per = g.usize_in(20, 120);
        let mb: Arc<Mailbox<(usize, usize)>> = Arc::new(Mailbox::new(capacity));
        let producers: Vec<_> = (0..n_producers)
            .map(|p| {
                let mb = Arc::clone(&mb);
                std::thread::spawn(move || {
                    for i in 0..per {
                        assert!(mb.send((p, i)), "mailbox closed early");
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..n_consumers)
            .map(|_| {
                let mb = Arc::clone(&mb);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = mb.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        mb.close();
        let mut all: Vec<(usize, usize)> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        assert_eq!(all.len(), n_producers * per, "messages lost or duplicated");
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n_producers * per, "duplicated messages");
    });
}

#[test]
fn prop_sim_conserves_jobs_and_is_deterministic() {
    let nets: Vec<Network> = ["mpcnn", "mnist"]
        .iter()
        .map(|n| Network::new(zoo::load(n).unwrap(), 32).unwrap())
        .collect();
    check("sim-conserves", 6, |g: &mut Gen| {
        let net = g.choose(&nets);
        let frames = g.usize_in(1, 12);
        let spec = if g.bool() {
            SimSpec::synergy(net, frames)
        } else {
            SimSpec::static_fixed(net, frames)
        };
        let r1 = simulate(&spec, net);
        // The simulator mirrors the unified pool: CONV tiles + one
        // im2col job per CONV layer + one FC job per connected layer.
        let profile = net.pool_job_profile();
        let expected: usize = profile.iter().sum::<usize>() * frames;
        assert_eq!(r1.jobs_executed, expected as u64, "job conservation");
        for class in JobClass::ALL {
            assert_eq!(
                r1.jobs_by_class[class.index()],
                (profile[class.index()] * frames) as u64,
                "{}",
                class.label()
            );
        }
        // determinism
        let r2 = simulate(&spec, net);
        assert_eq!(r1.makespan_s, r2.makespan_s);
        assert_eq!(r1.jobs_stolen, r2.jobs_stolen);
        // utilization is a valid fraction
        assert!((0.0..=1.0001).contains(&r1.cluster_util));
    });
}

/// The plug-in contract, pinned independently of `RemoteShard`: a registry
/// containing ONLY an out-of-tree backend (none of the in-tree ones) must
/// serve the full model zoo through the pool with `inline_fallbacks == 0`
/// for its supported classes — every job reaches the custom backend, the
/// forward stays correct, and nothing silently falls back inline.
#[test]
fn prop_out_of_tree_only_registry_serves_zoo_without_fallback() {
    /// An out-of-tree backend: correct native compute plus an execution
    /// ledger the test audits.
    struct Counting {
        inner: NativeGemm,
        executed: Arc<AtomicU64>,
    }
    impl Accelerator for Counting {
        fn id(&self) -> &str {
            "out-of-tree"
        }
        fn supports(&self, _class: JobClass) -> bool {
            true
        }
        fn execute(&mut self, job: &Job) -> anyhow::Result<synergy::mm::job::JobResult> {
            self.executed.fetch_add(1, Ordering::Relaxed);
            self.inner.execute(job)
        }
    }

    let nets: Vec<Network> = zoo::ZOO
        .iter()
        .map(|n| Network::new(zoo::load(n).unwrap(), 32).unwrap())
        .collect();
    let covered = std::cell::Cell::new(0usize);
    check("plugin-only-registry", zoo::ZOO.len(), |g: &mut Gen| {
        let net = g.choose(&nets);
        // Cover the whole zoo across the run: case i always includes
        // model i, plus a random second pick for topology variety.
        let forced = &nets[covered.get() % nets.len()];
        covered.set(covered.get() + 1);

        let executed = Arc::new(AtomicU64::new(0));
        let mut registry = BackendRegistry::new();
        let ledger = Arc::clone(&executed);
        // "neon" is just the key the config's members resolve to — the
        // registry holds ONLY this out-of-tree entry (latest-wins would
        // have replaced an in-tree one; here there is nothing to replace).
        registry.register(BackendSpec::new("neon", move || {
            Ok(Box::new(Counting {
                inner: NativeGemm,
                executed: Arc::clone(&ledger),
            }) as Box<dyn Accelerator>)
        }));
        assert_eq!(registry.names(), vec!["neon"], "no built-ins registered");

        let mut hw = HwConfig::default_zc702();
        hw.clusters = vec![ClusterCfg {
            name: "plugin".into(),
            neon: g.usize_in(1, 2),
            big_neon: 0,
            remote: Vec::new(),
            pes: Vec::new(),
        }];
        let mut options = PoolOptions::new(hw, ComputeMode::Native, g.bool());
        options.registry = Some(Arc::new(registry));
        let pool = DelegatePool::start(&options).unwrap();
        let dispatcher = pool.dispatcher();

        let mut expected_jobs = 0u64;
        for net in [forced, net] {
            let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
            let router = PoolRouter::new(net, dispatcher.clone(), &assignment);
            let frame = g.usize_in(0, 500) as u64;
            let x = net.make_input(frame);
            let y = net.forward_with(&x, &router.frame(frame));
            let want = net.forward_reference(&x);
            assert!(
                y.allclose(&want, 1e-4, 1e-5),
                "{}: {}",
                net.config.name,
                y.max_abs_diff(&want)
            );
            expected_jobs += net.pool_job_profile().iter().sum::<usize>() as u64;
        }

        let report = pool.shutdown().unwrap();
        assert_eq!(report.inline_fallbacks, 0, "job fell back inline");
        assert_eq!(report.jobs_executed, expected_jobs);
        assert_eq!(
            executed.load(Ordering::Relaxed),
            expected_jobs,
            "every job must reach the out-of-tree backend"
        );
        assert_eq!(report.delegate_failures, 0);
    });
    assert!(covered.get() >= zoo::ZOO.len(), "zoo not fully covered");
}

#[test]
fn prop_network_forward_always_distribution() {
    let nets: Vec<Network> = zoo::ZOO
        .iter()
        .map(|n| Network::new(zoo::load(n).unwrap(), 32).unwrap())
        .collect();
    check("forward-distribution", 8, |g: &mut Gen| {
        let net = g.choose(&nets);
        let frame = g.usize_in(0, 1000) as u64;
        let y = net.forward_reference(&net.make_input(frame));
        let sum: f32 = y.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "{}: sum {sum}", net.config.name);
        assert!(y.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    });
}
