//! Int8 accuracy harness: quantize/dequantize round-trip properties,
//! zoo-wide quantized-forward drift bounds, and the mixed-capability
//! routing contract — a pool whose members lack the Q8 capability bits
//! must run a quantized net through the dequantized f32 job classes
//! (same integer codes, scale applied after) with ZERO inline fallbacks.

use std::sync::Arc;

use synergy::accel::{Accelerator, BackendRegistry, BackendSpec, NativeGemm};
use synergy::config::{zoo, ClusterCfg, HwConfig};
use synergy::mm::{ClassMask, JobClass, OperandView, TileGrid};
use synergy::nn::{dequantize, quantize, quantize_scale};
use synergy::nn::{MatExec, NativeExec, Network, QuantizedNetwork};
use synergy::rt::{ComputeMode, DelegatePool, PoolOptions, PoolRouter};
use synergy::sched::static_map;
use synergy::util::rng::XorShift64Star;

fn mk(name: &str) -> Network {
    Network::new(zoo::load(name).unwrap(), 32).unwrap()
}

/// A native executor that denies the Q8 capability: quantized forwards
/// through it exercise the dequantized fallback arm with the plain f32
/// kernels — the oracle the pooled fallback path must match bitwise.
struct NoQ8;
impl MatExec for NoQ8 {
    fn conv_gemm(
        &self,
        layer_idx: usize,
        grid: TileGrid,
        a: OperandView,
        b: OperandView,
    ) -> Vec<f32> {
        NativeExec.conv_gemm(layer_idx, grid, a, b)
    }
    fn supports_q8(&self) -> bool {
        false
    }
}

/// Round-trip property: with the calibrated symmetric scale (max-abs on
/// 127), no value clamps, so dequantize(quantize(v)) lands within half a
/// code step of v — the defining guarantee of the scheme.
#[test]
fn roundtrip_error_is_bounded_by_half_a_code_step() {
    for seed in [1u64, 7, 42, 1234] {
        for n in [1usize, 3, 257, 4096] {
            let data = XorShift64Star::new(seed).fill_f32(n, 2.5);
            let scale = quantize_scale(&data);
            assert!(scale > 0.0);
            let codes = quantize(&data, scale);
            let back = dequantize(&codes, scale);
            let bound = 0.5 * scale * (1.0 + 1e-5);
            for (i, (&v, &r)) in data.iter().zip(&back).enumerate() {
                assert!(
                    (v - r).abs() <= bound,
                    "seed {seed} n {n} elem {i}: |{v} - {r}| > {bound}"
                );
            }
            // Symmetric codes: negating the input negates the codes (the
            // -128 code is never produced).
            let neg: Vec<f32> = data.iter().map(|v| -v).collect();
            let neg_codes = quantize(&neg, scale);
            for (c, nc) in codes.iter().zip(&neg_codes) {
                assert_eq!(*nc, -*c);
            }
        }
    }
}

/// Codes are a fixed point of the round trip: re-quantizing a dequantized
/// plane reproduces the codes exactly (dequantized values sit on the code
/// lattice, far from rounding boundaries).
#[test]
fn requantizing_dequantized_codes_is_exact() {
    let data = XorShift64Star::new(9).fill_f32(1000, 4.0);
    let scale = quantize_scale(&data);
    let codes = quantize(&data, scale);
    let again = quantize(&dequantize(&codes, scale), scale);
    assert_eq!(codes, again);
}

/// Outliers beyond the calibrated range clamp symmetrically to ±127.
#[test]
fn out_of_range_values_clamp_to_the_code_range() {
    let codes = quantize(&[1e9, -1e9, 0.0, 0.5], 0.5);
    assert_eq!(codes, vec![127, -127, 0, 1]);
}

/// Zoo-wide drift harness: every zoo network, calibrated on its own
/// deterministic input frames, must produce a quantized forward that is
/// (a) a valid probability vector and (b) close to the f32 reference.
/// The 0.2 band on softmax outputs is deliberately generous — per-layer
/// symmetric int8 drifts a few percent on these depths — while still
/// failing loudly on any broken scale, pack, or dequantize boundary
/// (those produce essentially uncorrelated distributions).
#[test]
fn zoo_wide_q8_forward_tracks_the_f32_reference() {
    for name in zoo::ZOO {
        let q = QuantizedNetwork::calibrate(mk(name), 1);
        let x = q.net().make_input(0);
        let got = q.forward_with(&x, &NativeExec);
        assert_eq!(got.shape(), &[10], "{name}");
        let sum: f32 = got.data().iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "{name}: softmax sum {sum}");
        assert!(
            got.data().iter().all(|v| v.is_finite() && *v >= 0.0),
            "{name}: non-probability output"
        );
        let want = q.net().forward_reference(&x);
        assert!(
            got.allclose(&want, 0.2, 0.2),
            "{name}: q8 drifted {} from reference",
            got.max_abs_diff(&want)
        );
    }
}

/// The dequantized fallback runs the SAME integer codes through f32
/// kernels — its only divergence from the int8 path is f32 rounding in
/// the accumulation, so the two outputs agree tightly on every net light
/// enough for the loop (the full zoo is covered functionally above).
#[test]
fn fallback_path_tracks_q8_path_on_light_nets() {
    for name in ["mnist", "mpcnn", "cifar_darknet"] {
        let q = QuantizedNetwork::calibrate(mk(name), 1);
        let x = q.net().make_input(2);
        let a = q.forward_with(&x, &NativeExec);
        let b = q.forward_with(&x, &NoQ8);
        assert!(
            a.allclose(&b, 1e-3, 1e-3),
            "{name}: fallback drifted {} from q8",
            a.max_abs_diff(&b)
        );
    }
}

/// Mixed-capability routing: a pool whose only member class revokes Q8
/// (`BackendSpec::quantized(false)`) reports `supports_q8() == false`, so
/// the quantized forward ships the dequantized f32 job profile — no Q8
/// job ever reaches the dispatcher, nothing runs inline, and the output
/// is bit-identical to the native fallback oracle.
#[test]
fn q8_blind_pool_forces_dequantized_routing_with_zero_fallbacks() {
    let mut hw = HwConfig::default_zc702();
    hw.clusters = vec![ClusterCfg {
        name: "deq".into(),
        neon: 2,
        big_neon: 0,
        remote: Vec::new(),
        pes: Vec::new(),
    }];
    let mut registry = BackendRegistry::new();
    registry.register(
        BackendSpec::new("neon", || {
            Ok(Box::new(NativeGemm) as Box<dyn Accelerator>)
        })
        .quantized(false),
    );
    let mut options = PoolOptions::new(hw, ComputeMode::Native, false);
    options.registry = Some(Arc::new(registry));
    let pool = DelegatePool::start(&options).unwrap();
    for mask in pool.dispatcher().accept_masks() {
        assert_eq!(mask.intersect(ClassMask::Q8), ClassMask::NONE);
    }

    let q = QuantizedNetwork::calibrate(mk("mnist"), 1);
    let assignment = static_map::assign(&q.net().conv_infos(), pool.clusters());
    let router = PoolRouter::new(q.net(), pool.dispatcher(), &assignment);
    let x = q.net().make_input(0);
    let exec = router.frame(0);
    assert!(!exec.supports_q8(), "no member claims Q8");
    let y = q.forward_with(&x, &exec);
    let want = q.forward_with(&x, &NoQ8);
    assert_eq!(
        y.data(),
        want.data(),
        "pooled dequantized path must match the native fallback bitwise"
    );

    let report = pool.shutdown().unwrap();
    // The fallback issues exactly the f32 job profile of the wrapped net:
    // the Q8 classes never leave the executor.
    let profile = q.net().pool_job_profile();
    for class in JobClass::ALL {
        assert_eq!(
            report.per_class_jobs[class.index()],
            profile[class.index()] as u64,
            "{}",
            class.label()
        );
    }
    assert_eq!(report.per_class_jobs[JobClass::ConvTileQ8.index()], 0);
    assert_eq!(report.per_class_jobs[JobClass::FcGemmQ8.index()], 0);
    assert_eq!(report.per_class_jobs[JobClass::FcGemmBatchQ8.index()], 0);
    assert_eq!(report.inline_fallbacks, 0, "capability masking, not inlining");
}

/// The capable-pool twin of the routing test: default members claim Q8,
/// the same net moves every GEMM class to its int8 twin, and the pooled
/// result is bit-identical to the all-native q8 forward (exact i32
/// accumulation on both sides).
#[test]
fn q8_capable_pool_dispatches_int8_twins_bit_identically() {
    let mut hw = HwConfig::default_zc702();
    hw.clusters = vec![ClusterCfg {
        name: "q8".into(),
        neon: 2,
        big_neon: 0,
        remote: Vec::new(),
        pes: Vec::new(),
    }];
    let options = PoolOptions::new(hw, ComputeMode::Native, false);
    let pool = DelegatePool::start(&options).unwrap();

    let q = QuantizedNetwork::calibrate(mk("mnist"), 1);
    let assignment = static_map::assign(&q.net().conv_infos(), pool.clusters());
    let router = PoolRouter::new(q.net(), pool.dispatcher(), &assignment);
    let x = q.net().make_input(4);
    let exec = router.frame(0);
    assert!(exec.supports_q8());
    let y = q.forward_with(&x, &exec);
    let want = q.forward_with(&x, &NativeExec);
    assert_eq!(y.data(), want.data(), "pooled q8 must match native q8");

    let report = pool.shutdown().unwrap();
    assert_eq!(report.per_class_jobs[JobClass::ConvTile.index()], 0);
    assert_eq!(report.per_class_jobs[JobClass::FcGemm.index()], 0);
    assert!(report.per_class_jobs[JobClass::ConvTileQ8.index()] > 0);
    assert!(report.per_class_jobs[JobClass::FcGemmQ8.index()] > 0);
    assert_eq!(report.inline_fallbacks, 0);
}
