//! End-to-end integration: the complete threaded Synergy runtime — layer
//! threads, mailboxes, cluster job queues, delegate threads executing the
//! AOT **Pallas kernel through PJRT**, work-stealing thief — against both
//! the Rust reference forward and the AOT full-model oracle.
//!
//! This is the proof that all three layers compose: L1 (Pallas kernel
//! artifact) runs inside L3 (Rust coordinator) and reproduces L2's (JAX
//! model) numerics on streaming frames.  Requires `make artifacts`.

use std::sync::Arc;

use synergy::config::zoo;
use synergy::nn::Network;
use synergy::rt::driver::run_stream;
use synergy::rt::{ComputeMode, RtOptions};
use synergy::runtime::{default_artifacts_dir, ModelOracle};
use synergy::tensor::Tensor;

fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

#[test]
fn pjrt_pipeline_matches_reference_and_oracle() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let net = Arc::new(Network::new(zoo::load("mpcnn").unwrap(), 32).unwrap());
    let frames: Vec<(u64, Tensor)> = (0..4).map(|f| (f, net.make_input(f))).collect();
    let report = run_stream(
        Arc::clone(&net),
        RtOptions {
            compute: ComputeMode::Pjrt,
            ..Default::default()
        },
        frames,
    )
    .unwrap();
    assert_eq!(report.outputs.len(), 4);

    // vs Rust reference forward
    for (frame_id, out) in &report.outputs {
        let want = net.forward_reference(&net.make_input(*frame_id));
        assert!(
            out.allclose(&want, 1e-4, 1e-4),
            "frame {frame_id} vs reference: {}",
            out.max_abs_diff(&want)
        );
    }

    // vs AOT model oracle through PJRT (frame 0)
    let oracle = ModelOracle::load(&default_artifacts_dir(), "mpcnn").unwrap();
    let params: Vec<&[f32]> = net.params.iter().map(|p| p.tensor.data()).collect();
    let x = net.make_input(0);
    let oracle_out = oracle.run(x.data(), &params).unwrap();
    let got = &report.outputs[0].1;
    let oracle_t = Tensor::from_vec(&[oracle_out.len()], oracle_out);
    assert!(
        got.allclose(&oracle_t, 1e-4, 1e-4),
        "vs oracle: {}",
        got.max_abs_diff(&oracle_t)
    );
}

#[test]
fn pjrt_pipeline_mnist_stream_with_stealing() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let net = Arc::new(Network::new(zoo::load("mnist").unwrap(), 32).unwrap());
    let frames: Vec<(u64, Tensor)> = (0..3).map(|f| (f, net.make_input(f))).collect();
    let report = run_stream(
        Arc::clone(&net),
        RtOptions {
            compute: ComputeMode::Pjrt,
            work_stealing: true,
            ..Default::default()
        },
        frames,
    )
    .unwrap();
    for (frame_id, out) in &report.outputs {
        let want = net.forward_reference(&net.make_input(*frame_id));
        assert!(
            out.allclose(&want, 1e-4, 1e-4),
            "frame {frame_id}: {}",
            out.max_abs_diff(&want)
        );
    }
    let expected: usize = net
        .conv_infos()
        .iter()
        .map(|ci| ci.grid.num_jobs())
        .sum::<usize>()
        * 3;
    assert_eq!(report.jobs_executed, expected as u64);
}
