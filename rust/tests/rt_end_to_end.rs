//! End-to-end integration: the complete threaded Synergy runtime — layer
//! threads, mailboxes, cluster job queues, delegate threads executing the
//! AOT **Pallas kernel through PJRT**, work-stealing thief — against both
//! the Rust reference forward and the AOT full-model oracle.
//!
//! This is the proof that all three layers compose: L1 (Pallas kernel
//! artifact) runs inside L3 (Rust coordinator) and reproduces L2's (JAX
//! model) numerics on streaming frames.  The PJRT cases need
//! `make artifacts` plus the `pjrt` cargo feature; the native case runs
//! everywhere (CI included).

use std::sync::Arc;

use synergy::config::zoo;
use synergy::nn::Network;
use synergy::rt::driver::run_stream;
#[cfg(feature = "pjrt")]
use synergy::rt::ComputeMode;
use synergy::rt::RtOptions;
#[cfg(feature = "pjrt")]
use synergy::runtime::{default_artifacts_dir, ModelOracle};
use synergy::tensor::Tensor;

#[cfg(feature = "pjrt")]
fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

/// Native end-to-end: the full threaded pipeline on every zoo model —
/// streams must reproduce the reference forward with no artifacts at all.
#[test]
fn native_pipeline_matches_reference_across_zoo() {
    for name in ["mpcnn", "cifar_darknet", "cifar_full"] {
        let net = Arc::new(Network::new(zoo::load(name).unwrap(), 32).unwrap());
        let frames: Vec<(u64, Tensor)> = (0..3).map(|f| (f, net.make_input(f))).collect();
        let report = run_stream(Arc::clone(&net), RtOptions::default(), frames).unwrap();
        assert_eq!(report.outputs.len(), 3, "{name}");
        for (frame_id, out) in &report.outputs {
            let want = net.forward_reference(&net.make_input(*frame_id));
            assert!(
                out.allclose(&want, 1e-4, 1e-4),
                "{name} frame {frame_id}: {}",
                out.max_abs_diff(&want)
            );
        }
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_pipeline_matches_reference_and_oracle() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let net = Arc::new(Network::new(zoo::load("mpcnn").unwrap(), 32).unwrap());
    let frames: Vec<(u64, Tensor)> = (0..4).map(|f| (f, net.make_input(f))).collect();
    let report = run_stream(
        Arc::clone(&net),
        RtOptions {
            compute: ComputeMode::Pjrt,
            ..Default::default()
        },
        frames,
    )
    .unwrap();
    assert_eq!(report.outputs.len(), 4);

    // vs Rust reference forward
    for (frame_id, out) in &report.outputs {
        let want = net.forward_reference(&net.make_input(*frame_id));
        assert!(
            out.allclose(&want, 1e-4, 1e-4),
            "frame {frame_id} vs reference: {}",
            out.max_abs_diff(&want)
        );
    }

    // vs AOT model oracle through PJRT (frame 0)
    let oracle = ModelOracle::load(&default_artifacts_dir(), "mpcnn").unwrap();
    let params: Vec<&[f32]> = net.params.iter().map(|p| p.data()).collect();
    let x = net.make_input(0);
    let oracle_out = oracle.run(x.data(), &params).unwrap();
    let got = &report.outputs[0].1;
    let oracle_t = Tensor::from_vec(&[oracle_out.len()], oracle_out);
    assert!(
        got.allclose(&oracle_t, 1e-4, 1e-4),
        "vs oracle: {}",
        got.max_abs_diff(&oracle_t)
    );
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_pipeline_mnist_stream_with_stealing() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let net = Arc::new(Network::new(zoo::load("mnist").unwrap(), 32).unwrap());
    let frames: Vec<(u64, Tensor)> = (0..3).map(|f| (f, net.make_input(f))).collect();
    let report = run_stream(
        Arc::clone(&net),
        RtOptions {
            compute: ComputeMode::Pjrt,
            work_stealing: true,
            ..Default::default()
        },
        frames,
    )
    .unwrap();
    for (frame_id, out) in &report.outputs {
        let want = net.forward_reference(&net.make_input(*frame_id));
        assert!(
            out.allclose(&want, 1e-4, 1e-4),
            "frame {frame_id}: {}",
            out.max_abs_diff(&want)
        );
    }
    // Member-level routing: ALL classes are pool jobs even in PJRT mode
    // (the NEON members of the mixed cluster serve FC/im2col).
    let expected: usize = net.pool_job_profile().iter().sum::<usize>() * 3;
    assert_eq!(report.jobs_executed, expected as u64);
    assert_eq!(report.inline_fallbacks, 0);
}
