//! Deterministic scheduling harness: a seeded, virtual-time, single-
//! threaded model of the member-level pool that pins the scheduler's
//! invariants down with real [`Job`] values — no OS threads, no timing
//! races, every run reproducible from its seed.
//!
//! Invariants proven over randomized mixed-cluster topologies (PE, NEON,
//! and remote-shard member kinds — the latter with partial masks and a
//! nonzero steal ship gate):
//! * **(a) per-class job conservation** — submitted = executed
//!   (+ stolen-then-executed), per class, and every job id exactly once;
//! * **(b) no inline fallback** whenever at least one member anywhere
//!   supports the class (and exactly one fallback per job whose class no
//!   member supports);
//! * **(c) steal accounting balance** — what the thief reports moved
//!   equals what the victims' sub-queues lost, per class.
//!
//! `SCHED_SEED=<n>` selects a fresh deterministic seed family (see
//! `util::proptest`); CI sweeps a small matrix of values.
//!
//! The second half drives the *real* `DelegatePool` with a NEON+PE mixed
//! cluster in PJRT-stub mode (the acceptance scenario): FC and im2col
//! jobs must execute on NEON members with the inline-fallback counter at
//! zero.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use synergy::accel::remote::{remote_class_mask, shard_backend_name};
use synergy::accel::{Accelerator, BackendRegistry, BackendSpec, NativeGemm};
use synergy::cluster::QueueBank;
use synergy::config::{zoo, ClusterCfg, HwConfig};
use synergy::mm::job::{jobs_for_gemm, ClassMask, Classed, Job, JobClass, JobResult};
use synergy::mm::TileGrid;
use synergy::nn::Network;
use synergy::rt::{ComputeMode, DelegatePool, PoolOptions, PoolRouter};
use synergy::sched::static_map;
use synergy::sched::worksteal::{choose_victim_weighted, steal_amount, StealPolicy};
use synergy::util::proptest::{check, Gen};

/// One simulated member: capability mask, service rate (k-steps per
/// virtual second), shipping cost (seconds a steal into this member's
/// cluster must beat — 0 for local members, > 0 for remote shards), and
/// per-class execution counters.
struct Member {
    cluster: usize,
    caps: ClassMask,
    rate: f64,
    ship: f64,
    is_remote: bool,
    busy_until: f64,
    executed_by_class: [u64; JobClass::COUNT],
}

/// Random mixed-cluster topology: 1–3 clusters, each 1–3 members that are
/// CONV-only "PEs", all-class "NEONs", or remote "shards" (CONV-tile +
/// fused-FC masks with a nonzero shipping cost) with differing rates.
fn random_topology(g: &mut Gen) -> (Vec<Arc<QueueBank<Job>>>, Vec<Member>) {
    let n_clusters = g.usize_in(1, 3);
    let banks: Vec<Arc<QueueBank<Job>>> =
        (0..n_clusters).map(|_| Arc::new(QueueBank::new())).collect();
    let mut members = Vec::new();
    for cluster in 0..n_clusters {
        for _ in 0..g.usize_in(1, 3) {
            let kind = g.usize_in(0, 3);
            let (caps, rate_scale, ship, is_remote) = match kind {
                // PEs drain faster, like the hardware.
                0 | 1 => (
                    ClassMask::of(&[JobClass::ConvTile]),
                    4.0,
                    0.0,
                    false,
                ),
                2 => (ClassMask::all(), 1.0, 0.0, false),
                // Remote shard: big far-end pool, but steals into it must
                // beat a shipping cost.
                _ => (
                    ClassMask::of(&[JobClass::ConvTile, JobClass::FcGemmBatch]),
                    6.0,
                    0.5 + g.usize_in(0, 3) as f64,
                    true,
                ),
            };
            members.push(Member {
                cluster,
                caps,
                rate: rate_scale * (1 + g.usize_in(0, 2)) as f64,
                ship,
                is_remote,
                busy_until: 0.0,
                executed_by_class: [0; JobClass::COUNT],
            });
        }
    }
    (banks, members)
}

/// Generate a random job of `class` with tiny operands (real numerics,
/// cheap to execute if anyone wants to) and a unique id.
fn random_job(g: &mut Gen, class: JobClass, id: &mut u64) -> Vec<Job> {
    match class {
        JobClass::ConvTile => {
            let grid = TileGrid::new(g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 8), 8);
            let a = Arc::new(vec![0.5f32; grid.m * grid.n]);
            let b = Arc::new(vec![0.25f32; grid.n * grid.p]);
            jobs_for_gemm(0, 0, grid, a, b, id)
        }
        JobClass::FcGemm => {
            let (out_n, in_n) = (g.usize_in(1, 8), g.usize_in(1, 8));
            let w = Arc::new(vec![1.0f32; out_n * in_n]);
            let x = Arc::new(vec![1.0f32; in_n]);
            let job = Job::fc(*id, 0, 0, out_n, in_n, w, x, 8);
            *id += 1;
            vec![job]
        }
        JobClass::Im2col => {
            let (c, h, w) = (g.usize_in(1, 3), g.usize_in(3, 6), g.usize_in(3, 6));
            let input = Arc::new(vec![0.0f32; c * h * w]);
            let job = Job::im2col(*id, 0, 0, (c, h, w), 3, 1, 1, input, 8);
            *id += 1;
            vec![job]
        }
        JobClass::FcGemmBatch => {
            let (out_n, in_n, batch) = (g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 6));
            let w = Arc::new(vec![1.0f32; out_n * in_n]);
            let xb = Arc::new(vec![1.0f32; in_n * batch]);
            let job = Job::fc_batch(*id, 0, 0, out_n, in_n, batch, w, xb, 8);
            *id += 1;
            vec![job]
        }
    }
}

/// The dispatcher's routing rule, mirrored over the harness topology:
/// any cluster with a capable member, least virtual load first.  (The
/// real dispatcher additionally adds a per-class shipping penalty for
/// remote-only clusters; placement choice does not affect the
/// conservation/mask invariants this harness pins, so the mirror stays
/// backlog-only.)
fn route(banks: &[Arc<QueueBank<Job>>], members: &[Member], class: JobClass) -> Option<usize> {
    (0..banks.len())
        .filter(|&c| {
            members
                .iter()
                .any(|m| m.cluster == c && m.caps.supports(class))
        })
        .min_by(|&a, &b| {
            let la = banks[a].len();
            let lb = banks[b].len();
            la.cmp(&lb)
        })
}

#[test]
fn deterministic_harness_conserves_jobs_and_never_falls_back() {
    // Across the randomized runs the fused batched-FC class AND the
    // remote member kind must actually be exercised — per-class
    // conservation for FcGemmBatch and mask/ship discipline for remote
    // members are part of the contract, not accidents of the seed.
    let fused_submitted = std::cell::Cell::new(0u64);
    let remote_executed = std::cell::Cell::new(0u64);
    check("sched-deterministic", 25, |g: &mut Gen| {
        let (banks, mut members) = random_topology(g);
        let n_clusters = banks.len();
        let policy = StealPolicy::default();
        // Per-cluster accept masks (union) and service rates, exactly as
        // DelegatePool::start derives them.
        let accepts: Vec<ClassMask> = (0..n_clusters)
            .map(|c| {
                members
                    .iter()
                    .filter(|m| m.cluster == c)
                    .fold(ClassMask::NONE, |acc, m| acc.union(m.caps))
            })
            .collect();
        let rates: Vec<f64> = (0..n_clusters)
            .map(|c| {
                members
                    .iter()
                    .filter(|m| m.cluster == c)
                    .map(|m| m.rate)
                    .sum()
            })
            .collect();

        // --- submit -------------------------------------------------
        let mut next_id = 0u64;
        let mut submitted_by_class = [0u64; JobClass::COUNT];
        let mut submitted_ids = HashSet::new();
        let mut inline_fallbacks = 0u64;
        let mut unsupported_jobs = 0u64;
        for _ in 0..g.usize_in(5, 40) {
            let class = *g.choose(&JobClass::ALL);
            for job in random_job(g, class, &mut next_id) {
                let supported = members.iter().any(|m| m.caps.supports(class));
                match route(&banks, &members, class) {
                    Some(cluster) => {
                        assert!(supported, "route() invented a capable member");
                        assert!(submitted_ids.insert(job.desc.job_id));
                        submitted_by_class[class.index()] += 1;
                        banks[cluster].push(job);
                    }
                    None => {
                        // Invariant (b): fallback fires ONLY when no
                        // member of the whole topology supports it.
                        assert!(
                            !supported,
                            "inline fallback with a capable member present"
                        );
                        unsupported_jobs += 1;
                        inline_fallbacks += 1;
                    }
                }
            }
        }
        assert_eq!(inline_fallbacks, unsupported_jobs);

        // --- virtual-time execution + thief ------------------------
        let mut thief_moved_by_class = [0u64; JobClass::COUNT];
        let mut victim_lost_by_class = [0u64; JobClass::COUNT];
        let mut executed_ids = HashSet::new();
        let mut clock = 0.0f64;
        let mut steps = 0u64;
        loop {
            steps += 1;
            assert!(steps < 1_000_000, "harness failed to converge (scheduler bug)");
            // Next free member (deterministic tie-break by index) pops
            // from its own cluster's bank through its own mask.
            let Some(mi) = (0..members.len()).min_by(|&a, &b| {
                members[a]
                    .busy_until
                    .partial_cmp(&members[b].busy_until)
                    .unwrap()
                    .then(a.cmp(&b))
            }) else {
                break;
            };
            clock = clock.max(members[mi].busy_until);
            let cluster = members[mi].cluster;
            let caps = members[mi].caps;
            if let Some(job) = banks[cluster].try_pop_any(caps) {
                let class = job.class();
                assert!(
                    caps.supports(class),
                    "member popped a class outside its mask"
                );
                assert!(executed_ids.insert(job.desc.job_id), "job executed twice");
                members[mi].executed_by_class[class.index()] += 1;
                members[mi].busy_until = clock + job.ksteps() as f64 / members[mi].rate;
                continue;
            }
            // Member idle → one thief pass for its cluster, with the
            // idle member's mask intersected with the destination accept
            // union (exactly the thief-loop math).
            let counts: Vec<[usize; JobClass::COUNT]> =
                banks.iter().map(|b| b.class_counts()).collect();
            let mut cap = accepts[cluster].intersect(caps);
            // Class-level ship gate mirror: the destination's cheapest
            // capable member sets each class's shipping cost; classes
            // whose heaviest victim backlog drains in place faster than
            // it ships are pruned from the steal mask.
            for class in JobClass::ALL {
                let i = class.index();
                if !cap.supports_index(i) {
                    continue;
                }
                let ship = members
                    .iter()
                    .filter(|m| m.cluster == cluster && m.caps.supports(class))
                    .map(|m| m.ship)
                    .fold(f64::INFINITY, f64::min);
                if !ship.is_finite() || ship <= 0.0 {
                    continue;
                }
                let heaviest = counts
                    .iter()
                    .zip(&rates)
                    .enumerate()
                    .filter(|(v, _)| *v != cluster)
                    .map(|(_, (c, rate))| {
                        c[i] as f64 * policy.class_cost[i] / rate.max(1e-12)
                    })
                    .fold(0.0f64, f64::max);
                if heaviest <= ship {
                    cap = cap.without(class);
                }
            }
            let stealable: Vec<usize> = counts
                .iter()
                .map(|c| {
                    c.iter()
                        .enumerate()
                        .filter(|(i, _)| cap.supports_index(*i))
                        .map(|(_, &n)| n)
                        .sum()
                })
                .collect();
            let loads: Vec<f64> = counts
                .iter()
                .zip(&rates)
                .map(|(c, rate)| {
                    c.iter()
                        .enumerate()
                        .filter(|(i, _)| cap.supports_index(*i))
                        .map(|(i, &n)| n as f64 * policy.class_cost[i])
                        .sum::<f64>()
                        / rate.max(1e-12)
                })
                .collect();
            let mut idle = HashSet::new();
            idle.insert(cluster);
            let Some(victim) =
                choose_victim_weighted(&stealable, &loads, &idle, policy.min_victim_len)
            else {
                // Nothing stealable anywhere: this member is done.  If
                // every member is done and the banks hold only jobs no
                // one can serve, we are finished (none exist: submission
                // only enqueued routable jobs).
                if banks.iter().all(|b| b.is_empty()) {
                    break;
                }
                // Jobs remain but not for this member's cluster right
                // now; park it past the current horizon.
                let horizon = members
                    .iter()
                    .map(|m| m.busy_until)
                    .fold(clock, f64::max);
                members[mi].busy_until = horizon + 1e-9;
                continue;
            };
            let before = banks[victim].class_counts();
            let stolen = banks[victim].steal_where(steal_amount(stealable[victim]), cap);
            let after = banks[victim].class_counts();
            // Invariant (c): thief-side and victim-side reports balance.
            for (i, (b, a)) in before.iter().zip(&after).enumerate() {
                victim_lost_by_class[i] += (b - a) as u64;
            }
            for job in &stolen {
                assert!(cap.supports_index(job.class_index()), "steal leaked class");
                thief_moved_by_class[job.class_index()] += 1;
            }
            banks[cluster].push_batch(stolen);
        }

        // --- invariants --------------------------------------------
        // (c) steal accounting balances between thief and victims.
        assert_eq!(thief_moved_by_class, victim_lost_by_class);
        // (a) per-class conservation: submitted = executed, every id once.
        let mut executed_by_class = [0u64; JobClass::COUNT];
        for m in &members {
            for (acc, n) in executed_by_class.iter_mut().zip(&m.executed_by_class) {
                *acc += n;
            }
            for class in JobClass::ALL {
                assert!(
                    m.caps.supports(class) || m.executed_by_class[class.index()] == 0,
                    "member executed a class outside its mask"
                );
            }
        }
        assert_eq!(executed_by_class, submitted_by_class, "per-class conservation");
        assert_eq!(executed_ids, submitted_ids, "job ids lost or duplicated");
        fused_submitted
            .set(fused_submitted.get() + submitted_by_class[JobClass::FcGemmBatch.index()]);
        for m in &members {
            if m.is_remote {
                // Mask discipline for the remote kind, explicitly: no
                // single-column FC, no im2col — ever.
                assert_eq!(m.executed_by_class[JobClass::FcGemm.index()], 0);
                assert_eq!(m.executed_by_class[JobClass::Im2col.index()], 0);
                remote_executed
                    .set(remote_executed.get() + m.executed_by_class.iter().sum::<u64>());
            }
        }
    });
    assert!(
        fused_submitted.get() > 0,
        "randomized runs never submitted an FcGemmBatch job"
    );
    assert!(
        remote_executed.get() > 0,
        "randomized runs never executed a job on a remote member"
    );
}

/// Acceptance scenario on the real pool: the default ZC702 cluster-0 is a
/// NEON+PE mixed cluster; under PJRT-stub mode (no `pjrt` feature — the
/// PE backend computes natively but keeps its CONV-only capability mask)
/// a full forward pass must execute its FC and im2col jobs on NEON
/// members, with the inline-fallback counter at zero.
#[test]
fn mixed_cluster_pjrt_stub_full_forward_runs_fc_on_neon() {
    let net = Arc::new(Network::new(zoo::load("mnist").unwrap(), 32).unwrap());
    let options = PoolOptions::new(
        synergy::config::HwConfig::default_zc702(),
        ComputeMode::Pjrt,
        true,
    );
    let pool = DelegatePool::start(&options).unwrap();
    let accels = pool.accels();
    let assignment = static_map::assign(&net.conv_infos(), pool.clusters());
    let router = PoolRouter::new(&net, pool.dispatcher(), &assignment);

    let frames = 3u64;
    for f in 0..frames {
        let x = net.make_input(f);
        let exec = router.frame(f);
        let y = net.forward_with(&x, &exec);
        let want = net.forward_reference(&x);
        assert!(y.allclose(&want, 1e-4, 1e-5), "frame {f}: {}", y.max_abs_diff(&want));
    }
    let report = pool.shutdown().unwrap();

    // The acceptance criteria, verbatim.
    assert_eq!(report.inline_fallbacks, 0, "inline fallback must never trigger");
    let profile = net.pool_job_profile();
    assert_eq!(
        report.per_class_jobs[JobClass::FcGemm.index()],
        (profile[JobClass::FcGemm.index()] as u64) * frames
    );
    assert_eq!(
        report.per_class_jobs[JobClass::Im2col.index()],
        (profile[JobClass::Im2col.index()] as u64) * frames
    );
    // FC/im2col executed by NEON members (nonzero per-class delegate
    // counters), and by nobody else.
    let mut neon_fc = 0u64;
    let mut neon_im2col = 0u64;
    for accel in &accels {
        let by_class = report.per_accel_by_class[accel.id];
        if accel.is_fpga() {
            assert_eq!(
                by_class[JobClass::FcGemm.index()] + by_class[JobClass::Im2col.index()],
                0,
                "{} (CONV-only) executed a non-CONV job",
                accel.name
            );
        } else {
            neon_fc += by_class[JobClass::FcGemm.index()];
            neon_im2col += by_class[JobClass::Im2col.index()];
        }
    }
    assert!(neon_fc > 0, "NEON members never executed an FC job");
    assert!(neon_im2col > 0, "NEON members never executed an im2col job");
    // Steal accounting balances per class, and no stolen class exceeds
    // what was dispatched.
    assert_eq!(
        report.stolen_by_class.iter().sum::<u64>(),
        report.jobs_stolen
    );
    for class in JobClass::ALL {
        assert!(
            report.stolen_by_class[class.index()] <= report.per_class_jobs[class.index()],
            "{}: stolen more than dispatched",
            class.label()
        );
    }
    assert_eq!(report.dispatched_by_class, report.per_class_jobs);
}

/// A backend that holds every job until the test opens its gate — the
/// deterministic way to pile a known backlog onto one cluster's bank.
struct GatedGemm {
    open: Arc<AtomicBool>,
}

impl Accelerator for GatedGemm {
    fn id(&self) -> &str {
        "gated"
    }
    fn supports(&self, _class: JobClass) -> bool {
        true
    }
    fn cost(&self, job: &Job) -> f64 {
        job.ksteps() as f64
    }
    fn execute(&mut self, job: &Job) -> anyhow::Result<JobResult> {
        while !self.open.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(1));
        }
        Ok(job.execute_native())
    }
}

/// Measured-cost placement between two remote-kind members (ISSUE 7): the
/// dispatcher prefers the shard whose *measured* link cost is lower, a
/// probe-driven cost change flips placement with no queue state at all,
/// backlog flips it exactly when the queue crosses the measured cost gap,
/// and an evicted link disappears from routing entirely.
#[test]
fn measured_link_costs_steer_placement_between_two_shards() {
    let cheap_addr = "127.0.0.1:11";
    let dear_addr = "127.0.0.1:12";
    let mut hw = HwConfig::default_zc702();
    hw.clusters = vec![
        ClusterCfg {
            name: "cheap".into(),
            neon: 0,
            big_neon: 0,
            remote: vec![cheap_addr.into()],
            pes: Vec::new(),
        },
        ClusterCfg {
            name: "dear".into(),
            neon: 0,
            big_neon: 0,
            remote: vec![dear_addr.into()],
            pes: Vec::new(),
        },
    ];

    // Local stand-ins under the shard backend names: the pool treats both
    // as remote-kind members (shared per-address link cells — the ones a
    // prober would feed), but execution stays in-process and
    // deterministic.  The cheap shard's backend is gated so its bank can
    // hold a known backlog; the dear shard executes immediately.
    let gate = Arc::new(AtomicBool::new(false));
    let mut registry = BackendRegistry::new();
    let builder_gate = Arc::clone(&gate);
    registry.register(
        BackendSpec::new(&shard_backend_name(cheap_addr), move || {
            Ok(Box::new(GatedGemm {
                open: Arc::clone(&builder_gate),
            }) as Box<dyn Accelerator>)
        })
        .caps(remote_class_mask())
        .overhead_ksteps(20.0),
    );
    registry.register(
        BackendSpec::new(&shard_backend_name(dear_addr), || {
            Ok(Box::new(NativeGemm) as Box<dyn Accelerator>)
        })
        .caps(remote_class_mask())
        .overhead_ksteps(100.0),
    );

    let mut options = PoolOptions::new(hw, ComputeMode::Native, false);
    options.drain_extra = 0; // a blocked delegate holds exactly one job
    options.registry = Some(Arc::new(registry));
    let pool = Arc::new(DelegatePool::start(&options).unwrap());
    let dispatcher = pool.dispatcher();
    let ci = JobClass::ConvTile.index();
    let cheap_link = Arc::clone(&pool.routes()[0].members()[0].link);
    let dear_link = Arc::clone(&pool.routes()[1].members()[0].link);
    let kstep = pool.routes()[0].members()[0].kstep_seconds;

    // Idle queues: the statically cheaper link (20 vs 100 k-steps) wins.
    assert_eq!(dispatcher.route(JobClass::ConvTile, None), Some(0));

    // Measured placement, no queue state involved: probes report the
    // cheap link degraded past the dear one → placement flips; further
    // probes measuring it healthy again blend the estimate back down and
    // placement returns.  (First probe replaces the static prior; later
    // ones EWMA-blend, so recovery takes a few pings — exactly the
    // anti-flap behavior the blend is for.)
    cheap_link.record_probe(300.0 * kstep, kstep, 2000.0);
    assert_eq!(dispatcher.route(JobClass::ConvTile, None), Some(1));
    for _ in 0..12 {
        cheap_link.record_probe(20.0 * kstep, kstep, 2000.0);
    }
    assert!(cheap_link.overhead_ksteps() < 100.0);
    assert_eq!(dispatcher.route(JobClass::ConvTile, None), Some(0));
    dear_link.record_probe(100.0 * kstep, kstep, 2000.0);

    // Backlog crossing the measured gap: with the gate closed, un-hinted
    // jobs queue on the cheap shard until its backlog-per-measured-rate
    // exceeds the measured overhead gap, then new work routes dear.
    let gap_s = pool.routes()[1].class_overhead_s(ci) - pool.routes()[0].class_overhead_s(ci);
    assert!(gap_s > 0.0);
    let flip_jobs = (gap_s * pool.routes()[0].class_rate(ci)).ceil() as usize + 2;
    let total = flip_jobs + 3;
    let grid = TileGrid::new(8, 8, 8, 8);
    let a = Arc::new(vec![0.5f32; 64]);
    let b = Arc::new(vec![0.25f32; 64]);
    let mut workers = Vec::new();
    for _ in 0..total {
        let pool = Arc::clone(&pool);
        let (a, b) = (Arc::clone(&a), Arc::clone(&b));
        workers.push(std::thread::spawn(move || {
            let dispatcher = pool.dispatcher();
            let mut id = dispatcher.reserve_job_ids(1);
            let jobs = jobs_for_gemm(0, 0, grid, a, b, &mut id);
            for job in jobs {
                let want = job.execute_native().data;
                assert_eq!(dispatcher.execute_job(job).data, want);
            }
        }));
    }
    let mut waited = 0u64;
    while dispatcher.route(JobClass::ConvTile, None) != Some(1) {
        waited += 1;
        assert!(
            waited < 2500,
            "backlog of {total} gated jobs never tipped routing to the dear shard"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    gate.store(true, Ordering::Relaxed);
    for w in workers {
        w.join().unwrap();
    }

    // Queues drained: back to the cheaper link…
    assert_eq!(dispatcher.route(JobClass::ConvTile, None), Some(0));
    // …until it dies: an evicted link leaves routing entirely.
    assert!(cheap_link.evict());
    assert_eq!(dispatcher.route(JobClass::ConvTile, None), Some(1));

    let pool = Arc::try_unwrap(pool).unwrap_or_else(|_| panic!("pool still shared"));
    let report = pool.shutdown().unwrap();
    assert_eq!(report.jobs_executed, total as u64);
    assert_eq!(report.inline_fallbacks, 0);
    assert_eq!(report.delegate_failures, 0);
    assert_eq!(report.evicted_members, 1);
}
