//! Deterministic serving-tier harness: the SLO-tier invariants pinned on
//! a virtual clock, plus the zero-downtime hot-swap contract on the real
//! server.
//!
//! The virtual-time half drives the **real** admission queue and
//! micro-batcher through `sim::tiered` (explicit-`now` entry points, no
//! wall-clock reads between events), so ordering assertions are exact and
//! replayable:
//! * interactive traffic is never shed while batch-lane work is being
//!   admitted (per-tier depth budgets);
//! * within a lane, deadlined requests dispatch in EDF order;
//! * the batch-lane escape ratio serves bulk work every Nth pop under a
//!   sustained foreground flood;
//! * per-tier conservation: arrivals = served + shed + expired, per tier,
//!   on randomized traces (`SCHED_SEED=<n>` selects the case family; CI
//!   sweeps a matrix).
//!
//! The real-server half proves the hot-swap contract: a mid-stream weight
//! swap loses zero in-flight requests, every response matches the
//! reference forward of the weight version it reports — never the other
//! version's — and each version packs its CONV weights exactly once.

use std::sync::Arc;
use std::time::Duration;

use synergy::config::zoo;
use synergy::nn::Network;
use synergy::serve::{Request, ServeOptions, Server, SloTier};
use synergy::sim::tiered::{simulate_tiered, TieredArrival, TieredSpec};
use synergy::util::proptest::{check, Gen};

fn arrival(at_us: u64, tier: SloTier, stream_id: usize) -> TieredArrival {
    TieredArrival {
        at_us,
        net_id: 0,
        stream_id,
        tier,
        deadline_us: None,
    }
}

// ---------------------------------------------------------------------------
// Virtual-time tier invariants (deterministic, no threads).
// ---------------------------------------------------------------------------

#[test]
fn interactive_never_shed_while_batch_floods() {
    // Batch tier floods a shallow lane; interactive fills its own budget.
    let mut spec = TieredSpec {
        lane_depth: 4,
        ..TieredSpec::default()
    };
    spec.batch.max_batch = 2;
    for i in 0..50 {
        spec.arrivals.push(arrival(0, SloTier::Batch, i));
    }
    for i in 0..4 {
        spec.arrivals.push(arrival(1, SloTier::Interactive, i));
    }
    spec.arrivals.sort_by_key(|a| a.at_us);
    let out = simulate_tiered(&spec);
    let ii = SloTier::Interactive.index();
    let bi = SloTier::Batch.index();
    assert_eq!(
        out.admission.shed[ii], 0,
        "interactive must never shed while batch admits: {out:?}"
    );
    assert_eq!(out.completed_by_tier()[ii], 4, "all interactive served");
    assert_eq!(out.admission.shed[bi], 46, "batch flood sheds only itself");
    assert_eq!(out.completed_by_tier()[bi], 4, "admitted batch work drains");
}

#[test]
fn edf_orders_dispatch_within_a_lane() {
    // One tier, every request deadlined, batch size 1: dispatch order
    // must be exactly ascending due time, regardless of submit order.
    let mut spec = TieredSpec {
        service_base_us: 1_000,
        service_per_item_us: 0,
        ..TieredSpec::default()
    };
    spec.batch.max_batch = 1;
    let deadlines_us = [90_000u64, 30_000, 70_000, 50_000, 110_000];
    for (i, d) in deadlines_us.iter().enumerate() {
        spec.arrivals.push(TieredArrival {
            at_us: 0,
            net_id: 0,
            stream_id: i,
            tier: SloTier::Standard,
            deadline_us: Some(*d),
        });
    }
    let out = simulate_tiered(&spec);
    assert_eq!(out.served.len(), 5);
    let mut by_dispatch = out.served.clone();
    by_dispatch.sort_by_key(|s| s.batch_index);
    let dues: Vec<u64> = by_dispatch.iter().map(|s| s.due_us.unwrap()).collect();
    let mut sorted = dues.clone();
    sorted.sort_unstable();
    assert_eq!(dues, sorted, "EDF violated: {dues:?}");
}

#[test]
fn escape_ratio_serves_batch_every_nth_pop_under_flood() {
    // 30 interactive + 6 batch, all backlogged at t=0, escape every 3rd
    // pop, batch size 1: pops 3, 6, 9, … serve the batch lane.
    let mut spec = TieredSpec {
        escape_every: 3,
        lane_depth: 64,
        ..TieredSpec::default()
    };
    spec.batch.max_batch = 1;
    for i in 0..30 {
        spec.arrivals.push(arrival(0, SloTier::Interactive, i % 4));
    }
    for i in 0..6 {
        spec.arrivals.push(arrival(0, SloTier::Batch, 10 + i));
    }
    let out = simulate_tiered(&spec);
    assert_eq!(out.served.len(), 36);
    assert_eq!(out.dropped(), 0);
    let mut by_dispatch = out.served.clone();
    by_dispatch.sort_by_key(|s| s.batch_index);
    for (pos, s) in by_dispatch.iter().enumerate() {
        let expect_batch = (pos + 1) % 3 == 0 && pos < 18;
        assert_eq!(
            s.tier == SloTier::Batch,
            expect_batch,
            "pop {} served {:?}; escape schedule violated",
            pos + 1,
            s.tier
        );
    }
    // Starvation-proof: the last batch request finishes well before the
    // interactive flood is drained.
    let last_batch = by_dispatch
        .iter()
        .filter(|s| s.tier == SloTier::Batch)
        .map(|s| s.batch_index)
        .max()
        .unwrap();
    assert!(last_batch < 18, "batch work starved to the flood's tail");
}

#[test]
fn deadline_storm_prunes_in_lane_and_counts_per_tier() {
    // The half-expired-lane regression at the harness level: a storm of
    // short deadlines against a slow server expires *in the lane* (pop
    // pruning), with exact per-tier accounting and zero silent loss.
    let mut spec = TieredSpec {
        service_base_us: 20_000,
        service_per_item_us: 0,
        ..TieredSpec::default()
    };
    spec.batch.max_batch = 1;
    for i in 0..8 {
        spec.arrivals.push(TieredArrival {
            at_us: 0,
            net_id: 0,
            stream_id: i,
            tier: SloTier::Interactive,
            deadline_us: Some(if i % 2 == 0 { 10_000 } else { 500_000 }),
        });
    }
    let out = simulate_tiered(&spec);
    let ii = SloTier::Interactive.index();
    let expired = out.admission.expired[ii] + out.expired_in_batcher[ii];
    assert_eq!(
        out.served.len() as u64 + expired,
        8,
        "conservation: {out:?}"
    );
    assert!(expired >= 3, "the short-deadline half must mostly lapse");
    // No served request was dispatched past its deadline by more than the
    // service time (it was live at dispatch — pruning is at pop time).
    for s in &out.served {
        if let Some(due) = s.due_us {
            let dispatch = s.finish_us - spec.service_base_us;
            assert!(
                dispatch <= due,
                "request dispatched after lapsing: {s:?}"
            );
        }
    }
}

#[test]
fn randomized_tier_traces_conserve_and_replay() {
    check("serving-tier-invariants", 16, |g: &mut Gen| {
        let n = g.usize_in(8, 40);
        let mut spec = TieredSpec {
            lane_depth: g.usize_in(2, 16),
            escape_every: g.usize_in(0, 4) as u64,
            ready_cap: g.usize_in(1, 2),
            service_base_us: 100 + 100 * g.usize_in(0, 19) as u64,
            service_per_item_us: 50 * g.usize_in(0, 4) as u64,
            ..TieredSpec::default()
        };
        spec.batch.max_batch = g.usize_in(1, 4);
        let mut per_tier_arrivals = [0u64; SloTier::COUNT];
        let mut t = 0u64;
        for i in 0..n {
            t += 500 * g.usize_in(0, 4) as u64;
            let tier = *g.choose(&SloTier::ALL);
            per_tier_arrivals[tier.index()] += 1;
            spec.arrivals.push(TieredArrival {
                at_us: t,
                net_id: 0,
                stream_id: i % 5,
                tier,
                deadline_us: g.bool().then(|| 5_000 + 2_500 * g.usize_in(0, 30) as u64),
            });
        }
        let out = simulate_tiered(&spec);
        // (1) Per-tier conservation: every arrival is served, shed, or
        //     expired — nothing vanishes, nothing double-counts.
        let done = out.completed_by_tier();
        for ti in 0..SloTier::COUNT {
            assert_eq!(
                done[ti]
                    + out.admission.shed[ti]
                    + out.admission.expired[ti]
                    + out.expired_in_batcher[ti],
                per_tier_arrivals[ti],
                "tier {ti} leaked requests: {out:?}"
            );
        }
        // (2) A tier whose arrivals fit its lane depth never sheds (the
        //     other tiers' floods cannot displace it).
        for ti in 0..SloTier::COUNT {
            if per_tier_arrivals[ti] <= spec.lane_depth as u64 {
                assert_eq!(out.admission.shed[ti], 0, "tier {ti} displaced");
            }
        }
        // (3) Bit-deterministic replay.
        let again = simulate_tiered(&spec);
        let key = |s: &synergy::sim::tiered::Served| {
            (s.stream_id, s.seq, s.batch_index, s.submit_us, s.finish_us)
        };
        assert_eq!(
            out.served.iter().map(key).collect::<Vec<_>>(),
            again.served.iter().map(key).collect::<Vec<_>>(),
            "replay diverged"
        );
        assert_eq!(out.admission.shed, again.admission.shed);
        assert_eq!(out.admission.expired, again.admission.expired);
        assert_eq!(out.window_events, again.window_events);
    });
}

// ---------------------------------------------------------------------------
// Hot-swap on the real server (threads, real pool).
// ---------------------------------------------------------------------------

fn mk_named(name: &str) -> Arc<Network> {
    let mut cfg = zoo::load("mnist").unwrap();
    cfg.name = name.to_string();
    Arc::new(Network::new(cfg, 32).unwrap())
}

#[test]
fn hot_swap_mid_stream_loses_nothing_and_matches_pinned_version() {
    let v0 = mk_named("mnist");
    let v1 = mk_named("mnist_v2"); // same architecture, different weights
    let mut options = ServeOptions::default();
    options.batch.max_batch = 2;
    options.batch.window = Duration::from_millis(2);
    options.admission_depth = 64;
    let server = Server::start(vec![Arc::clone(&v0)], options).unwrap();
    assert_eq!(server.net_version(0), 0);

    // First half of the stream, then the swap lands mid-flight, then the
    // second half.  Inputs always come from v0's generator — the client
    // doesn't know (or care) which weights serve it.
    for seq in 0..8u64 {
        let req = Request::new(0, seq, 0, v0.make_input(seq));
        assert!(server.submit(req));
    }
    let new_version = server.hot_swap(0, Arc::clone(&v1)).unwrap();
    assert_eq!(new_version, 1);
    assert_eq!(server.net_version(0), 1);
    for seq in 8..16u64 {
        let req = Request::new(0, seq, 0, v0.make_input(seq));
        assert!(server.submit(req));
    }

    let (stats, responses) = server.shutdown().unwrap();
    // Zero loss across the swap: everything admitted completed.
    assert_eq!(stats.completed, 16, "hot-swap lost in-flight requests");
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.expired, 0);
    assert_eq!(stats.hot_swaps, 1);
    assert_eq!(responses.len(), 16);

    // Every response matches the reference forward of the version it
    // reports — and is farther from the other version's output, so the
    // version tag is load-bearing, not decorative.
    let mut served_by_version = [0u64; 2];
    for resp in &responses {
        assert!(resp.version <= 1, "impossible version {}", resp.version);
        served_by_version[resp.version as usize] += 1;
        let input = v0.make_input(resp.frame);
        let own = if resp.version == 0 { &v0 } else { &v1 };
        let other = if resp.version == 0 { &v1 } else { &v0 };
        let want = own.forward_reference(&input);
        let not_want = other.forward_reference(&input);
        let own_err = resp.output.max_abs_diff(&want);
        let other_err = resp.output.max_abs_diff(&not_want);
        assert!(
            own_err < 1e-4,
            "seq {} diverged from its pinned version {}: {own_err}",
            resp.seq,
            resp.version
        );
        assert!(
            other_err > own_err,
            "seq {} output does not distinguish the versions",
            resp.seq
        );
    }
    // The swap is observable: requests submitted after it ran on v1
    // (batches formed before it may legitimately drain on v0).
    assert!(served_by_version[1] >= 8, "post-swap requests must see v1");

    // Each version packed its CONV weights exactly once — serving across
    // a swap never repacks on the hot path.
    for net in [&v0, &v1] {
        for (idx, layer) in net.config.layers.iter().enumerate() {
            if layer.is_conv() {
                assert_eq!(net.weight_pack_count(idx), 1, "layer {idx} repacked");
            }
        }
    }
}

#[test]
fn hot_swap_rejects_incompatible_replacements() {
    let v0 = mk_named("mnist");
    let server = Server::start(vec![Arc::clone(&v0)], ServeOptions::default()).unwrap();
    // Different architecture: rejected, version unchanged.
    let other = Arc::new(Network::new(zoo::load("mpcnn").unwrap(), 32).unwrap());
    assert!(server.hot_swap(0, other).is_err());
    // Same architecture, different tile size: rejected.
    let retiled = {
        let mut cfg = zoo::load("mnist").unwrap();
        cfg.name = "mnist_t16".into();
        Arc::new(Network::new(cfg, 16).unwrap())
    };
    assert!(server.hot_swap(0, retiled).is_err());
    // Unknown slot: rejected.
    assert!(server.hot_swap(7, Arc::clone(&v0)).is_err());
    assert_eq!(server.net_version(0), 0, "failed swaps must not bump");
    let (stats, _) = server.shutdown().unwrap();
    assert_eq!(stats.hot_swaps, 0);
}

// ---------------------------------------------------------------------------
// Tier plumbing end to end on the real server.
// ---------------------------------------------------------------------------

#[test]
fn tiers_ride_through_the_real_server() {
    let v0 = mk_named("mnist");
    let mut options = ServeOptions::default();
    options.batch.max_batch = 4;
    options.batch.window = Duration::from_millis(2);
    // Exercise the tier-default deadline stamping with a roomy budget.
    options.hw.serving.interactive_deadline_ms = 60_000;
    let server = Server::start(vec![Arc::clone(&v0)], options).unwrap();
    for seq in 0..4u64 {
        let req = Request::new(0, seq, 0, v0.make_input(seq))
            .with_tier(SloTier::Interactive);
        assert!(server.submit(req));
    }
    for seq in 4..8u64 {
        let req =
            Request::new(1, seq, 0, v0.make_input(seq)).with_tier(SloTier::Batch);
        assert!(server.submit(req));
    }
    let (stats, responses) = server.shutdown().unwrap();
    assert_eq!(stats.completed, 8);
    assert_eq!(stats.completed_by_tier[SloTier::Interactive.index()], 4);
    assert_eq!(stats.completed_by_tier[SloTier::Batch.index()], 4);
    assert_eq!(stats.expired, 0, "60s default budget cannot lapse here");
    for resp in responses {
        let expect = if resp.seq < 4 {
            SloTier::Interactive
        } else {
            SloTier::Batch
        };
        assert_eq!(resp.tier, expect, "tier must ride through to the response");
        // Tiers never share a batch.
        assert!(resp.batch_size <= 4);
    }
    assert!(
        stats.tier_p99_ms[SloTier::Interactive.index()] > 0.0,
        "per-tier latency recorded"
    );
}
