"""Make `pytest python/tests/` work from the repo root (and `pytest tests/`
from python/): put this directory on sys.path so `compile` imports."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
