"""Make `pytest python/tests/` work from the repo root (and `pytest tests/`
from python/): put this directory on sys.path so `compile` imports.

CI runs the suite with only numpy+pytest installed; the L1/L2 suites need
JAX (Pallas) and hypothesis, so they are skipped at collection when those
are unavailable rather than erroring on import."""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

_have_jax = importlib.util.find_spec("jax") is not None
_have_hypothesis = importlib.util.find_spec("hypothesis") is not None

collect_ignore = []
if not _have_jax:
    collect_ignore.append("tests/test_aot.py")
if not (_have_jax and _have_hypothesis):
    collect_ignore += ["tests/test_kernel.py", "tests/test_model.py"]
