"""L2 correctness: the Synergy CONV lowering (im2col + tiled MM on the
Pallas kernel) must equal direct convolution; model forward must be a
valid probability vector; shapes must match the manifest contract."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import netcfg
from compile.kernels import ref


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape, dtype=np.float32)


# ----------------------------------------------------------- conv lowering


@pytest.mark.parametrize(
    "c,h,w,oc,ksize,stride,pad",
    [
        (1, 8, 8, 4, 3, 1, 1),
        (3, 16, 16, 8, 5, 1, 2),
        (3, 13, 11, 6, 3, 2, 1),
        (4, 9, 9, 5, 1, 1, 0),
        (2, 12, 12, 7, 3, 3, 0),
    ],
)
def test_conv_as_mm_equals_direct(c, h, w, oc, ksize, stride, pad):
    x = _rand((c, h, w), seed=c * h)
    wgt = _rand((oc, c, ksize, ksize), seed=oc)
    bias = _rand((oc,), seed=99)
    got = np.asarray(
        M.conv_as_mm(jnp.array(x), jnp.array(wgt), jnp.array(bias), stride, pad)
    )
    want = np.asarray(ref.conv2d_ref(jnp.array(x), jnp.array(wgt), jnp.array(bias), stride, pad))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(1, 4),
    hw=st.integers(6, 20),
    oc=st.integers(1, 8),
    ksize=st.sampled_from([1, 3, 5]),
    stride=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_as_mm_property(c, hw, oc, ksize, stride, seed):
    pad = ksize // 2
    x = _rand((c, hw, hw), seed)
    wgt = _rand((oc, c, ksize, ksize), seed ^ 1)
    bias = _rand((oc,), seed ^ 2)
    got = np.asarray(
        M.conv_as_mm(jnp.array(x), jnp.array(wgt), jnp.array(bias), stride, pad)
    )
    want = np.asarray(
        ref.conv2d_ref(jnp.array(x), jnp.array(wgt), jnp.array(bias), stride, pad)
    )
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_im2col_known_values():
    """3x3 single-channel, 2x2 kernel, stride 1, no pad — hand-checked."""
    x = jnp.arange(9, dtype=jnp.float32).reshape(1, 3, 3)
    col = np.asarray(ref.im2col_ref(x, 2, 1, 0))
    assert col.shape == (4, 4)
    np.testing.assert_array_equal(col[0], [0, 1, 3, 4])  # (ki=0,kj=0)
    np.testing.assert_array_equal(col[1], [1, 2, 4, 5])  # (ki=0,kj=1)
    np.testing.assert_array_equal(col[2], [3, 4, 6, 7])  # (ki=1,kj=0)
    np.testing.assert_array_equal(col[3], [4, 5, 7, 8])  # (ki=1,kj=1)


def test_im2col_pad_zero_fills():
    x = jnp.ones((1, 2, 2), dtype=jnp.float32)
    col = np.asarray(ref.im2col_ref(x, 3, 1, 1))
    # top-left output location reads the zero-padded corner
    assert col[0, 0] == 0.0
    assert col.shape == (9, 4)


# ------------------------------------------------------------ model forward


@pytest.mark.parametrize("name", netcfg.ZOO)
def test_model_forward_is_distribution(name):
    net = netcfg.load(name)
    params = [jnp.array(p) for p in M.init_params(net)]
    x = jnp.array(M.make_input(net))
    y = np.asarray(M.forward(net, params, x, use_pallas=False))
    assert y.shape == (10,)
    assert np.all(y >= 0.0)
    np.testing.assert_allclose(y.sum(), 1.0, rtol=1e-5)


def test_model_pallas_path_matches_jnp_path():
    net = netcfg.load("mpcnn")  # lightest model, keeps interpret-mode fast
    params = [jnp.array(p) for p in M.init_params(net)]
    x = jnp.array(M.make_input(net))
    y1 = np.asarray(M.forward(net, params, x, use_pallas=False))
    y2 = np.asarray(M.forward(net, params, x, use_pallas=True))
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", netcfg.ZOO)
def test_layer_shapes_consistent(name):
    net = netcfg.load(name)
    shapes = M.layer_shapes(net)
    assert len(shapes) == len(net.layers)
    assert shapes[-1] == (10,)  # all zoo models classify 10 classes


def test_table2_layer_counts():
    """Paper Table 2: CONV layer count and total layer count per model."""
    expect = {
        "cifar_darknet": (4, 9),
        "cifar_alex": (3, 8),
        "cifar_alex_plus": (3, 9),
        "cifar_full": (3, 9),
        "mnist": (2, 7),
        "svhn": (3, 8),
        "mpcnn": (3, 9),
    }
    for name, (convs, total) in expect.items():
        net = netcfg.load(name)
        got_convs = sum(1 for l in net.layers if l.kind == "convolutional")
        assert got_convs == convs, name
        assert len(net.layers) == total, name


def test_conv_gemm_dims_match_shapes():
    for name in netcfg.ZOO:
        net = netcfg.load(name)
        for d in M.conv_gemm_dims(net):
            layer = net.layers[d["layer"]]
            assert layer.kind == "convolutional"
            assert d["m"] == layer.geti("filters", 0)
            assert d["k_tiles"] == -(-d["n"] // 32)


# -------------------------------------------------------------- other layers


def test_maxpool_known():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4)
    y = np.asarray(ref.maxpool_ref(x, 2, 2))
    np.testing.assert_array_equal(y[0], [[5, 7], [13, 15]])


def test_avgpool_known():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4)
    y = np.asarray(ref.avgpool_ref(x, 2, 2))
    np.testing.assert_allclose(y[0], [[2.5, 4.5], [10.5, 12.5]])


def test_activations():
    x = jnp.array([-2.0, -0.5, 0.0, 1.5])
    np.testing.assert_allclose(ref.activate_ref(x, "relu"), [0, 0, 0, 1.5])
    np.testing.assert_allclose(
        ref.activate_ref(x, "leaky"), [-0.2, -0.05, 0, 1.5], rtol=1e-6
    )
    np.testing.assert_allclose(ref.activate_ref(x, "linear"), x)
    s = np.asarray(ref.activate_ref(x, "sigmoid"))
    assert np.all((s > 0) & (s < 1))


def test_batchnorm_identity_params():
    x = _rand((3, 4, 4), seed=0)
    g = jnp.ones(3)
    z = jnp.zeros(3)
    o = jnp.ones(3)
    y = np.asarray(ref.batchnorm_ref(jnp.array(x), g, z, z, o, eps=0.0))
    np.testing.assert_allclose(y, x, rtol=1e-6)


def test_softmax_invariance_to_shift():
    x = _rand((10,), seed=3)
    y1 = np.asarray(ref.softmax_ref(jnp.array(x)))
    y2 = np.asarray(ref.softmax_ref(jnp.array(x + 100.0)))
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)
