"""L1 correctness: Pallas tiled-MM kernels vs the pure-jnp oracle.

hypothesis sweeps shapes (including ragged borders — the paper's
zero-padding case) and values; every kernel variant must agree with ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.tiled_mm import (
    DEFAULT_TS,
    job_mm,
    matmul_tiled,
    matmul_tiled_masked,
    matmul_tiled_padded,
)


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape, dtype=np.float32)


# ---------------------------------------------------------------- job kernel


@pytest.mark.parametrize("k", [1, 2, 3, 4, 9, 13, 25])
def test_job_mm_matches_ref(k):
    a = _rand((k, DEFAULT_TS, DEFAULT_TS), seed=k)
    b = _rand((k, DEFAULT_TS, DEFAULT_TS), seed=1000 + k)
    got = np.asarray(job_mm(jnp.array(a), jnp.array(b)))
    want = np.asarray(ref.job_mm_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_job_mm_k1_is_plain_tile_product(seed=7):
    a = _rand((1, DEFAULT_TS, DEFAULT_TS), seed)
    b = _rand((1, DEFAULT_TS, DEFAULT_TS), seed + 1)
    got = np.asarray(job_mm(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, a[0] @ b[0], rtol=1e-5, atol=1e-4)


def test_job_mm_zero_inputs():
    z = np.zeros((4, DEFAULT_TS, DEFAULT_TS), np.float32)
    got = np.asarray(job_mm(jnp.array(z), jnp.array(z)))
    assert np.all(got == 0.0)


def test_job_mm_identity_tiles():
    """A = identity tiles → C = sum of B tiles."""
    k = 3
    a = np.stack([np.eye(DEFAULT_TS, dtype=np.float32)] * k)
    b = _rand((k, DEFAULT_TS, DEFAULT_TS), seed=5)
    got = np.asarray(job_mm(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, b.sum(axis=0), rtol=1e-5, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_job_mm_property(k, seed):
    a = _rand((k, DEFAULT_TS, DEFAULT_TS), seed)
    b = _rand((k, DEFAULT_TS, DEFAULT_TS), seed ^ 0xDEAD)
    got = np.asarray(job_mm(jnp.array(a), jnp.array(b)))
    want = np.asarray(ref.job_mm_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# ------------------------------------------------------------ full tiled MM


@pytest.mark.parametrize(
    "m,n,p",
    [(32, 32, 32), (64, 32, 96), (96, 64, 32), (128, 128, 128)],
)
def test_matmul_tiled_aligned(m, n, p):
    a = _rand((m, n), seed=m * 7 + n)
    b = _rand((n, p), seed=p * 13 + n)
    got = np.asarray(matmul_tiled(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize(
    "m,n,p",
    [(1, 1, 1), (33, 65, 31), (50, 70, 45), (32, 75, 1024), (64, 800, 196)],
)
def test_matmul_padded_ragged(m, n, p):
    """Ragged borders — the paper's zero-padding mechanism (§3.2.1)."""
    a = _rand((m, n), seed=m + n)
    b = _rand((n, p), seed=n + p)
    got = np.asarray(matmul_tiled_padded(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("m,n,p", [(33, 65, 31), (50, 70, 45)])
def test_matmul_masked_ragged(m, n, p):
    """In-kernel border detection variant must agree too."""
    a = _rand((m, n), seed=m * 3)
    b = _rand((n, p), seed=p * 3)
    got = np.asarray(matmul_tiled_masked(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=80),
    n=st.integers(min_value=1, max_value=80),
    p=st.integers(min_value=1, max_value=80),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_padded_property(m, n, p, seed):
    a = _rand((m, n), seed)
    b = _rand((n, p), seed ^ 0xBEEF)
    got = np.asarray(matmul_tiled_padded(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, a @ b, rtol=1e-3, atol=1e-3)


def test_masked_ignores_garbage_pad():
    """The masked kernel must re-derive validity from true bounds: results
    are unchanged even when the caller's pad region contains garbage.  We
    emulate by comparing padded vs masked on the same ragged input."""
    a = _rand((40, 50), seed=1)
    b = _rand((50, 33), seed=2)
    got1 = np.asarray(matmul_tiled_masked(jnp.array(a), jnp.array(b)))
    got2 = np.asarray(matmul_tiled_padded(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got1, got2, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------- ref sanity


def test_tiled_matmul_ref_equals_matmul():
    a = _rand((37, 53), seed=11)
    b = _rand((53, 29), seed=12)
    got = np.asarray(ref.tiled_matmul_ref(jnp.array(a), jnp.array(b), 32))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)
