"""AOT path: HLO text generation, manifest contract, prng determinism."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M, netcfg, prng


def test_hlo_text_roundtrippable_format():
    """Job kernel lowers to parseable HLO text with ENTRY and f32 tile types."""
    text = aot.lower_job_kernel(k=2)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "f32[2,32,32]" in text.replace(" ", "")


def test_needed_k_values_cover_zoo():
    nets = netcfg.load_zoo()
    ks = aot.needed_k_values(nets)
    assert ks == sorted(set(ks))
    for net in nets:
        for d in M.conv_gemm_dims(net):
            assert d["k_tiles"] in ks


def test_manifest_exists_and_indexes_artifacts():
    """`make artifacts` must have produced a consistent manifest."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built yet (run `make artifacts`)")
    with open(manifest_path) as f:
        man = json.load(f)
    assert man["tile_size"] == 32
    for jk in man["job_kernels"]:
        assert os.path.exists(os.path.join(art, jk["path"])), jk["path"]
    assert len(man["models"]) == len(netcfg.ZOO)
    for m in man["models"]:
        assert os.path.exists(os.path.join(art, m["path"])), m["path"]
        net = netcfg.load(m["name"])
        specs = M.param_specs(net)
        assert len(m["params"]) == len(specs)
        for got, want in zip(m["params"], specs):
            assert tuple(got["shape"]) == tuple(want["shape"])


def test_model_artifact_numerics_match_jax():
    """Execute the mpcnn HLO artifact via jax's own XLA client and compare
    against the eager forward — catches lowering bugs before Rust sees them."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    path = os.path.join(art, "model_mpcnn.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built yet")
    net = netcfg.load("mpcnn")
    params = M.init_params(net)
    x = M.make_input(net)
    want = np.asarray(
        M.forward(net, [jnp.array(p) for p in params], jnp.array(x), use_pallas=False)
    )
    # Re-lower and execute through jax.jit (same HLO source) as a proxy for
    # PJRT execution; the Rust integration test does the real PJRT run.
    got = np.asarray(
        jax.jit(
            lambda x, *p: M.forward(net, list(p), x, use_pallas=False)
        )(jnp.array(x), *[jnp.array(p) for p in params])
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------- prng


def test_prng_known_vector():
    """Pin the cross-language contract: these exact values are asserted in
    rust/src/util/rng.rs::tests as well.  If this test changes, change Rust."""
    r = prng.XorShift64Star(1)
    v = [r.next_u64() for _ in range(3)]
    assert v[0] == 0x47E4CE4B896CDD1D, hex(v[0])
    assert v[1] == 0xABCFA6A8E079651D, hex(v[1])
    r2 = prng.XorShift64Star(42)
    u = [round(r2.next_unit(), 9) for _ in range(2)]
    assert u == [round(u_, 9) for u_ in u]  # deterministic
    assert prng.fnv1a64("mnist/0/weights") == prng.fnv1a64("mnist/0/weights")
    assert prng.fnv1a64("a") != prng.fnv1a64("b")


def test_prng_fill_deterministic_and_scaled():
    a = prng.fill("m", 0, "weights", (4, 3), 2.0)
    b = prng.fill("m", 0, "weights", (4, 3), 2.0)
    np.testing.assert_array_equal(a, b)
    c = prng.fill("m", 0, "weights", (4, 3), 1.0)
    np.testing.assert_allclose(a, 2.0 * c, rtol=1e-6)
    assert np.all(np.abs(c) <= 0.5)


def test_init_params_match_specs():
    net = netcfg.load("mnist")
    specs = M.param_specs(net)
    params = M.init_params(net)
    assert len(params) == len(specs)
    for s, p in zip(specs, params):
        assert p.shape == tuple(s["shape"])
        assert p.dtype == np.float32


def test_batchnorm_var_positive():
    net = netcfg.load("cifar_full")
    specs = M.param_specs(net)
    params = M.init_params(net)
    for s, p in zip(specs, params):
        if s["name"] == "var":
            assert np.all(p > 0.0)


def test_make_input_in_unit_range():
    net = netcfg.load("mnist")
    x = M.make_input(net, frame=3)
    assert x.shape == net.input_shape
    assert np.all((x >= 0.0) & (x < 1.0))
    y = M.make_input(net, frame=4)
    assert not np.array_equal(x, y)
