"""Config parser: the single source of truth for the model zoo."""

import pytest

from compile import netcfg


def test_zoo_loads():
    nets = netcfg.load_zoo()
    assert [n.name for n in nets] == netcfg.ZOO


def test_parse_minimal():
    net = netcfg.parse_cfg_text(
        "t",
        """
        [net]
        height=8
        width=8
        channels=1
        [convolutional]
        filters=4
        size=3
        pad=1
        activation=relu
        [softmax]
        """,
    )
    assert net.input_shape == (1, 8, 8)
    assert [l.kind for l in net.layers] == ["convolutional", "softmax"]
    assert net.layers[0].geti("filters", 0) == 4
    assert net.layers[0].gets("activation", "?") == "relu"


def test_comments_and_blank_lines():
    net = netcfg.parse_cfg_text(
        "t",
        "# header\n[net]\nheight=4 # trailing\nwidth=4\nchannels=2\n\n[softmax]\n",
    )
    assert net.channels == 2


def test_errors():
    with pytest.raises(ValueError, match="first section"):
        netcfg.parse_cfg_text("t", "[convolutional]\nfilters=1\n")
    with pytest.raises(ValueError, match="unknown layer"):
        netcfg.parse_cfg_text(
            "t", "[net]\nheight=1\nwidth=1\nchannels=1\n[bogus]\n"
        )
    with pytest.raises(ValueError, match="height/width/channels"):
        netcfg.parse_cfg_text("t", "[net]\nheight=0\nwidth=1\nchannels=1\n")
    with pytest.raises(ValueError, match="key=value"):
        netcfg.parse_cfg_text("t", "[net]\nheight 3\n")
    with pytest.raises(ValueError, match="outside a section"):
        netcfg.parse_cfg_text("t", "height=3\n")
