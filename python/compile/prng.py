"""Cross-language deterministic parameter PRNG.

The Rust coordinator and the Python build path must materialize *identical*
f32 weights so that the end-to-end integration test (Rust pipeline output vs
AOT full-model HLO executed through PJRT) can compare numerics.  We use
xorshift64* with an FNV-1a-seeded state — both reimplemented bit-for-bit in
``rust/src/util/rng.rs``.

All arithmetic is exact: the uniform sample is formed from the top 24 bits
(exact in f64), scaled in f64, and only then cast to f32.
"""

from __future__ import annotations

import numpy as np

_MASK = (1 << 64) - 1
_XS_MULT = 0x2545F4914F6CDD1D
_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3


def fnv1a64(s: str) -> int:
    """FNV-1a 64-bit hash of a UTF-8 string (seed derivation)."""
    h = _FNV_OFFSET
    for byte in s.encode("utf-8"):
        h ^= byte
        h = (h * _FNV_PRIME) & _MASK
    return h


class XorShift64Star:
    """xorshift64* — tiny, fast, and trivially portable to Rust."""

    def __init__(self, seed: int):
        # State must be non-zero; fold the all-zeros seed to a fixed word.
        self.state = (seed & _MASK) or 0x9E3779B97F4A7C15

    def next_u64(self) -> int:
        x = self.state
        x ^= x >> 12
        x ^= (x << 25) & _MASK
        x ^= x >> 27
        self.state = x
        return (x * _XS_MULT) & _MASK

    def next_unit(self) -> float:
        """Uniform in [-0.5, 0.5), exactly representable in f64."""
        return (self.next_u64() >> 40) / float(1 << 24) - 0.5


def tensor_seed(model: str, layer: int, kind: str) -> int:
    """Canonical per-tensor seed: hash of ``model/layer/kind``."""
    return fnv1a64(f"{model}/{layer}/{kind}")


def fill(model: str, layer: int, kind: str, shape, scale: float) -> np.ndarray:
    """Deterministic tensor: f32(next_unit() * scale) in row-major order."""
    rng = XorShift64Star(tensor_seed(model, layer, kind))
    n = int(np.prod(shape))
    out = np.empty(n, dtype=np.float32)
    for i in range(n):
        out[i] = np.float32(rng.next_unit() * scale)
    return out.reshape(shape)
