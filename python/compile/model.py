"""L2 — JAX forward pass of the Synergy benchmark CNNs (paper Table 2).

The model is assembled from the same ``configs/*.cfg`` files the Rust
coordinator parses.  CONV layers go through the exact Synergy lowering
(darknet im2col → tiled matrix multiplication on the L1 Pallas kernel);
the "other layers" (§3.1.4: pooling, activation, fully-connected, batchnorm,
softmax) are the plain jnp oracles.

``make artifacts`` AOT-lowers (a) the per-K job kernels and (b) the full
per-model forward functions to HLO text for the Rust PJRT runtime.  Python
never runs at inference time.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import netcfg, prng
from .kernels import ref
from .kernels.tiled_mm import DEFAULT_TS, matmul_tiled_padded


def conv_out_hw(h: int, w: int, ksize: int, stride: int, pad: int) -> Tuple[int, int]:
    oh = (h + 2 * pad - ksize) // stride + 1
    ow = (w + 2 * pad - ksize) // stride + 1
    return oh, ow


def pool_out_hw(h: int, w: int, size: int, stride: int) -> Tuple[int, int]:
    return (h - size) // stride + 1, (w - size) // stride + 1


def layer_shapes(net: netcfg.NetCfg) -> List[Tuple[int, ...]]:
    """Output shape after every layer (input excluded).  Spatial layers give
    (C,H,W); flat layers give (N,)."""
    shapes: List[Tuple[int, ...]] = []
    cur: Tuple[int, ...] = net.input_shape
    for layer in net.layers:
        if layer.kind == "convolutional":
            c, h, w = cur
            oc = layer.geti("filters", 0)
            ksize = layer.geti("size", 1)
            stride = layer.geti("stride", 1)
            pad = layer.geti("pad", 0)
            oh, ow = conv_out_hw(h, w, ksize, stride, pad)
            cur = (oc, oh, ow)
        elif layer.kind in ("maxpool", "avgpool"):
            c, h, w = cur
            size = layer.geti("size", 2)
            stride = layer.geti("stride", size)
            oh, ow = pool_out_hw(h, w, size, stride)
            cur = (c, oh, ow)
        elif layer.kind == "connected":
            cur = (layer.geti("output", 0),)
        elif layer.kind in ("batchnorm", "dropout", "softmax"):
            pass  # shape-preserving
        else:
            raise ValueError(f"unhandled layer kind {layer.kind}")
        shapes.append(cur)
    return shapes


def param_specs(net: netcfg.NetCfg) -> List[Dict]:
    """Canonical flat parameter list: [{layer, name, shape, scale}, ...].

    Order and seeding must match ``rust/src/nn/network.rs`` exactly.
    """
    specs: List[Dict] = []
    cur: Tuple[int, ...] = net.input_shape
    for idx, layer in enumerate(net.layers):
        if layer.kind == "convolutional":
            c, h, w = cur
            oc = layer.geti("filters", 0)
            ksize = layer.geti("size", 1)
            stride = layer.geti("stride", 1)
            pad = layer.geti("pad", 0)
            fan_in = c * ksize * ksize
            scale = math.sqrt(2.0 / fan_in)
            specs.append(
                {"layer": idx, "name": "weights", "shape": (oc, c, ksize, ksize), "scale": scale}
            )
            specs.append({"layer": idx, "name": "bias", "shape": (oc,), "scale": 0.1})
            oh, ow = conv_out_hw(h, w, ksize, stride, pad)
            cur = (oc, oh, ow)
        elif layer.kind in ("maxpool", "avgpool"):
            c, h, w = cur
            size = layer.geti("size", 2)
            stride = layer.geti("stride", size)
            oh, ow = pool_out_hw(h, w, size, stride)
            cur = (c, oh, ow)
        elif layer.kind == "connected":
            n_in = int(np.prod(cur))
            n_out = layer.geti("output", 0)
            scale = math.sqrt(2.0 / n_in)
            specs.append(
                {"layer": idx, "name": "weights", "shape": (n_out, n_in), "scale": scale}
            )
            specs.append({"layer": idx, "name": "bias", "shape": (n_out,), "scale": 0.1})
            cur = (n_out,)
        elif layer.kind == "batchnorm":
            c = cur[0]
            for pname in ("gamma", "beta", "mean", "var"):
                specs.append({"layer": idx, "name": pname, "shape": (c,), "scale": 1.0})
        elif layer.kind in ("dropout", "softmax"):
            pass
        else:
            raise ValueError(f"unhandled layer kind {layer.kind}")
    return specs


def init_params(net: netcfg.NetCfg) -> List[np.ndarray]:
    """Deterministic seeded parameters (see prng.py for the contract).

    batchnorm gets shifted/positive-ized values so that var > 0:
      gamma = 1 + 0.1u, beta = 0.1u, mean = 0.1u, var = 1 + 0.5(u + 0.5).
    """
    out: List[np.ndarray] = []
    for spec in param_specs(net):
        base = prng.fill(net.name, spec["layer"], spec["name"], spec["shape"], 1.0)
        name = spec["name"]
        if name == "gamma":
            arr = (1.0 + 0.1 * base).astype(np.float32)
        elif name in ("beta", "mean"):
            arr = (0.1 * base).astype(np.float32)
        elif name == "var":
            arr = (1.0 + 0.5 * (base + 0.5)).astype(np.float32)
        else:
            arr = (base * np.float32(spec["scale"])).astype(np.float32)
        out.append(arr)
    return out


def make_input(net: netcfg.NetCfg, frame: int = 0) -> np.ndarray:
    """Deterministic synthetic input frame in [0,1) (paper: normalization
    scales inputs to [0,1] during preprocessing)."""
    base = prng.fill(net.name, 1_000_000 + frame, "input", net.input_shape, 1.0)
    return (base + 0.5).astype(np.float32)


def conv_as_mm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    stride: int,
    pad: int,
    *,
    ts: int = DEFAULT_TS,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """The Synergy CONV lowering: im2col + tiled MM (paper §3.1.1).

    x: (C,H,W); w: (OC,C,K,K) -> (OC,OH,OW).
    """
    oc, c, ksize, _ = w.shape
    _, h, wd = x.shape
    oh, ow = conv_out_hw(h, wd, ksize, stride, pad)
    col = ref.im2col_ref(x, ksize, stride, pad)  # (C*K*K, OH*OW)
    wmat = w.reshape(oc, c * ksize * ksize)
    if use_pallas:
        out = matmul_tiled_padded(wmat, col, ts=ts)
    else:
        out = ref.matmul_ref(wmat, col)
    return out.reshape(oc, oh, ow) + bias[:, None, None]


def forward(
    net: netcfg.NetCfg,
    params: List[jnp.ndarray],
    x: jnp.ndarray,
    *,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Full network forward pass; returns the class-probability vector."""
    specs = param_specs(net)
    p_by_layer: Dict[int, Dict[str, jnp.ndarray]] = {}
    for spec, arr in zip(specs, params):
        p_by_layer.setdefault(spec["layer"], {})[spec["name"]] = arr

    cur = x
    for idx, layer in enumerate(net.layers):
        if layer.kind == "convolutional":
            ps = p_by_layer[idx]
            stride = layer.geti("stride", 1)
            pad = layer.geti("pad", 0)
            cur = conv_as_mm(
                cur, ps["weights"], ps["bias"], stride, pad, use_pallas=use_pallas
            )
            cur = ref.activate_ref(cur, layer.gets("activation", "linear"))
        elif layer.kind == "maxpool":
            size = layer.geti("size", 2)
            cur = ref.maxpool_ref(cur, size, layer.geti("stride", size))
        elif layer.kind == "avgpool":
            size = layer.geti("size", 2)
            cur = ref.avgpool_ref(cur, size, layer.geti("stride", size))
        elif layer.kind == "connected":
            ps = p_by_layer[idx]
            cur = ref.connected_ref(cur.reshape(-1), ps["weights"], ps["bias"])
            cur = ref.activate_ref(cur, layer.gets("activation", "linear"))
        elif layer.kind == "batchnorm":
            ps = p_by_layer[idx]
            cur = ref.batchnorm_ref(
                cur, ps["gamma"], ps["beta"], ps["mean"], ps["var"]
            )
        elif layer.kind == "dropout":
            pass  # inference: no-op
        elif layer.kind == "softmax":
            cur = ref.softmax_ref(cur.reshape(-1))
        else:
            raise ValueError(f"unhandled layer kind {layer.kind}")
    return cur


def conv_gemm_dims(net: netcfg.NetCfg) -> List[Dict]:
    """GEMM dimensions per CONV layer: M=OC, N=C·K², P=OH·OW — the job
    geometry the Rust coordinator generates (K tiles = ceil(N/TS))."""
    dims = []
    cur = net.input_shape
    for idx, layer in enumerate(net.layers):
        if layer.kind == "convolutional":
            c, h, w = cur
            oc = layer.geti("filters", 0)
            ksize = layer.geti("size", 1)
            stride = layer.geti("stride", 1)
            pad = layer.geti("pad", 0)
            oh, ow = conv_out_hw(h, w, ksize, stride, pad)
            dims.append(
                {
                    "layer": idx,
                    "m": oc,
                    "n": c * ksize * ksize,
                    "p": oh * ow,
                    "k_tiles": -(-(c * ksize * ksize) // DEFAULT_TS),
                }
            )
            cur = (oc, oh, ow)
        elif layer.kind in ("maxpool", "avgpool"):
            c, h, w = cur
            size = layer.geti("size", 2)
            stride = layer.geti("stride", size)
            oh, ow = pool_out_hw(h, w, size, stride)
            cur = (c, oh, ow)
        elif layer.kind == "connected":
            cur = (layer.geti("output", 0),)
    return dims


def model_mops(net: netcfg.NetCfg) -> float:
    """Total MAC-ops ×2 in millions per frame (the paper's GOP accounting)."""
    total = 0.0
    cur = net.input_shape
    for layer in net.layers:
        if layer.kind == "convolutional":
            c, h, w = cur
            oc = layer.geti("filters", 0)
            ksize = layer.geti("size", 1)
            stride = layer.geti("stride", 1)
            pad = layer.geti("pad", 0)
            oh, ow = conv_out_hw(h, w, ksize, stride, pad)
            total += 2.0 * oc * oh * ow * c * ksize * ksize
            cur = (oc, oh, ow)
        elif layer.kind in ("maxpool", "avgpool"):
            c, h, w = cur
            size = layer.geti("size", 2)
            stride = layer.geti("stride", size)
            oh, ow = pool_out_hw(h, w, size, stride)
            total += c * oh * ow * size * size
            cur = (c, oh, ow)
        elif layer.kind == "connected":
            n_in = int(np.prod(cur))
            n_out = layer.geti("output", 0)
            total += 2.0 * n_in * n_out
            cur = (n_out,)
        elif layer.kind == "batchnorm":
            total += 2.0 * int(np.prod(cur))
    return total / 1e6
