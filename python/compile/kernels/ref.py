"""Pure-``jnp`` correctness oracles for the Pallas kernels and the JAX model.

Everything here is deliberately written in the most obvious way possible —
these functions define *what the answer is*; the Pallas kernels and the Rust
coordinator both get checked against them.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain f32 matrix multiply: C[M,P] = A[M,N] @ B[N,P]."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def job_mm_ref(a_tiles: jnp.ndarray, b_tiles: jnp.ndarray) -> jnp.ndarray:
    """Reference for one Synergy *job* (paper Fig 3): the output tile
    ``C(i,j) = sum_k A(i,k) @ B(k,j)`` over K pre-extracted (TS,TS) tiles.

    a_tiles, b_tiles: (K, TS, TS) f32.
    """
    return jnp.einsum(
        "kij,kjl->il", a_tiles, b_tiles, preferred_element_type=jnp.float32
    )


def tiled_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, ts: int) -> jnp.ndarray:
    """Tiled MM with zero-padding border semantics (paper §3.2.1 'Zero
    Padding in mm_tile'): identical result to ``matmul_ref`` — padding with
    zeros then cropping is an identity on the product."""
    m, n = a.shape
    n2, p = b.shape
    assert n == n2
    mp = -(-m // ts) * ts
    np_ = -(-n // ts) * ts
    pp = -(-p // ts) * ts
    a_pad = jnp.zeros((mp, np_), a.dtype).at[:m, :n].set(a)
    b_pad = jnp.zeros((np_, pp), b.dtype).at[:n, :p].set(b)
    return matmul_ref(a_pad, b_pad)[:m, :p]


def im2col_ref(x: jnp.ndarray, ksize: int, stride: int, pad: int) -> jnp.ndarray:
    """Darknet-layout im2col: x is (C,H,W); returns (C*ksize*ksize, OH*OW)
    where the row index varies as (c, ki, kj) c-major and the column as
    (oy, ox).

    This matches darknet's ``im2col_cpu`` and the Rust ``nn/im2col.rs``.
    """
    c, h, w = x.shape
    oh = (h + 2 * pad - ksize) // stride + 1
    ow = (w + 2 * pad - ksize) // stride + 1
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    rows = []
    for ci in range(c):
        for ki in range(ksize):
            for kj in range(ksize):
                patch = lax.dynamic_slice(
                    xp,
                    (ci, ki, kj),
                    (1, (oh - 1) * stride + 1, (ow - 1) * stride + 1),
                )[0, ::stride, ::stride]
                rows.append(patch.reshape(-1))
    return jnp.stack(rows, axis=0).astype(jnp.float32)


def conv2d_ref(
    x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray, stride: int, pad: int
) -> jnp.ndarray:
    """Direct convolution via lax.conv: x (C,H,W), w (OC,C,K,K) -> (OC,OH,OW)."""
    out = lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    return out + bias[:, None, None]


def maxpool_ref(x: jnp.ndarray, size: int, stride: int) -> jnp.ndarray:
    """Max pooling, darknet semantics (no padding, floor division)."""
    c, h, w = x.shape
    oh = (h - size) // stride + 1
    ow = (w - size) // stride + 1
    out = lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        (1, size, size),
        (1, stride, stride),
        "VALID",
    )
    return out[:, :oh, :ow].astype(jnp.float32)


def avgpool_ref(x: jnp.ndarray, size: int, stride: int) -> jnp.ndarray:
    """Average pooling, darknet semantics."""
    out = lax.reduce_window(
        x,
        0.0,
        lax.add,
        (1, size, size),
        (1, stride, stride),
        "VALID",
    )
    return (out / float(size * size)).astype(jnp.float32)


def connected_ref(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """Fully-connected layer: x (N,), w (OUT,N) -> (OUT,)."""
    return jnp.matmul(w, x, preferred_element_type=jnp.float32) + bias


def batchnorm_ref(
    x: jnp.ndarray,
    gamma: jnp.ndarray,
    beta: jnp.ndarray,
    mean: jnp.ndarray,
    var: jnp.ndarray,
    eps: float = 1e-5,
) -> jnp.ndarray:
    """Inference-time batch normalization over the channel dim of (C,H,W)."""
    inv = gamma / jnp.sqrt(var + eps)
    return x * inv[:, None, None] + (beta - mean * inv)[:, None, None]


def activate_ref(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    """Darknet activation functions used by the zoo."""
    if kind == "linear":
        return x
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "leaky":
        return jnp.where(x > 0.0, x, 0.1 * x)
    if kind == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-x))
    if kind == "tanh":
        return jnp.tanh(x)
    raise ValueError(f"unknown activation {kind!r}")


def softmax_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable softmax over a flat vector."""
    z = x - jnp.max(x)
    e = jnp.exp(z)
    return e / jnp.sum(e)
