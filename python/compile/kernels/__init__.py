"""L1 Pallas kernels (tiled MM — the Synergy PE compute hot-spot) and the
pure-jnp oracle used to validate them at build time."""
