"""L1 — Pallas tiled matrix-multiplication kernels.

These are the TPU re-thinking of Synergy's FPGA processing engine (PE,
paper §3.2.1 Listing 3).  The mapping (DESIGN.md §Hardware-Adaptation):

* BRAM tile buffers  →  VMEM blocks selected by ``BlockSpec``;
* HLS double-buffering (overlap fetch/compute)  →  Pallas' automatic
  HBM↔VMEM pipeline across grid steps;
* the ``mm_tile`` K-loop (steps ①–④ of the paper)  →  the innermost grid
  dimension accumulating into the output block;
* border detection / zero-padding  →  masked loads (``_masked_mm``) or
  caller-side zero-fill, both provided and both tested against ``ref.py``.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute real Mosaic custom-calls, and interpret-mode lowers to plain HLO that
the Rust runtime (xla crate, PJRT CPU) runs directly.  On a real TPU one
would instead pick MXU-shaped (128,128) blocks; we keep the paper's TS=32
and document the delta in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The paper sets TS=32 "based on empirical evaluation" (§4.1).
DEFAULT_TS = 32


def _job_mm_kernel(a_ref, b_ref, o_ref):
    """One grid step of a Synergy job: o += a_tiles[k] @ b_tiles[k].

    Grid is (K,).  BlockSpec feeds the k-th (TS,TS) tile of each operand;
    the output block index map is constant so the same VMEM tile is revisited
    (and accumulated) across all K steps — the Pallas idiom for the paper's
    local array ``c`` kept in BRAM while tiles stream through.
    """
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("ts",))
def job_mm(a_tiles: jnp.ndarray, b_tiles: jnp.ndarray, *, ts: int = DEFAULT_TS):
    """Compute one job's output tile from pre-extracted operand tiles.

    a_tiles, b_tiles: (K, TS, TS) f32  →  (TS, TS) f32.

    This is THE artifact the Rust delegate threads execute per job on the
    "FPGA PE" path (one AOT HLO per distinct K in the model zoo).
    """
    k = a_tiles.shape[0]
    assert a_tiles.shape == (k, ts, ts), a_tiles.shape
    assert b_tiles.shape == (k, ts, ts), b_tiles.shape
    return pl.pallas_call(
        _job_mm_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, ts, ts), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, ts, ts), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((ts, ts), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ts, ts), jnp.float32),
        interpret=True,
    )(a_tiles.astype(jnp.float32), b_tiles.astype(jnp.float32))


def _matmul_kernel(a_ref, b_ref, o_ref):
    """Full tiled-MM grid step: grid (M/TS, P/TS, N/TS), K innermost."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("ts",))
def matmul_tiled(a: jnp.ndarray, b: jnp.ndarray, *, ts: int = DEFAULT_TS):
    """C[M,P] = A[M,N] @ B[N,P] as a full Pallas tiled-MM (paper Listing 1).

    Dimensions must be multiples of TS (the padded fast path a PE sees);
    ragged shapes go through :func:`matmul_tiled_padded`.
    """
    m, n = a.shape
    n2, p = b.shape
    assert n == n2 and m % ts == 0 and n % ts == 0 and p % ts == 0
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // ts, p // ts, n // ts),
        in_specs=[
            pl.BlockSpec((ts, ts), lambda i, j, k: (i, k)),
            pl.BlockSpec((ts, ts), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((ts, ts), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, p), jnp.float32),
        interpret=True,
    )(a.astype(jnp.float32), b.astype(jnp.float32))


def matmul_tiled_padded(a: jnp.ndarray, b: jnp.ndarray, *, ts: int = DEFAULT_TS):
    """Ragged-shape tiled MM with the paper's zero-padding border semantics
    (§3.2.1 'Zero Padding in mm_tile'): out-of-bound reads return 0, writes
    past the border are dropped.  Implemented as zero-fill + crop, which is
    numerically identical."""
    m, n = a.shape
    n2, p = b.shape
    assert n == n2
    mp = -(-m // ts) * ts
    np_ = -(-n // ts) * ts
    pp = -(-p // ts) * ts
    a_pad = jnp.zeros((mp, np_), jnp.float32).at[:m, :n].set(a)
    b_pad = jnp.zeros((np_, pp), jnp.float32).at[:n, :p].set(b)
    return matmul_tiled(a_pad, b_pad, ts=ts)[:m, :p]


def _masked_mm_kernel(a_ref, b_ref, o_ref, *, ts: int, m: int, n: int, p: int):
    """Border detection *inside* the kernel (the exact paper mechanism):
    lanes beyond the true (m,n,p) bounds are zeroed on load, mirroring the
    PE's zero-fill when a fetch crosses the matrix border."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    row = i * ts + jax.lax.broadcasted_iota(jnp.int32, (ts, ts), 0)
    inner_a = k * ts + jax.lax.broadcasted_iota(jnp.int32, (ts, ts), 1)
    inner_b = k * ts + jax.lax.broadcasted_iota(jnp.int32, (ts, ts), 0)
    col = j * ts + jax.lax.broadcasted_iota(jnp.int32, (ts, ts), 1)

    a = jnp.where((row < m) & (inner_a < n), a_ref[...], 0.0)
    b = jnp.where((inner_b < n) & (col < p), b_ref[...], 0.0)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("ts",))
def matmul_tiled_masked(a: jnp.ndarray, b: jnp.ndarray, *, ts: int = DEFAULT_TS):
    """Tiled MM over pre-padded operands where masking is done in-kernel.

    Operands are physically padded up to tile multiples (so BlockSpec
    indexing stays in range under interpret mode) but the kernel *ignores*
    the pad contents — it re-derives validity from the true bounds, so the
    result is correct even if the caller filled the pad with garbage.
    Returns the (m, p) result cropped from the padded output.
    """
    m, n = a.shape
    n2, p = b.shape
    assert n == n2
    mp = -(-m // ts) * ts
    np_ = -(-n // ts) * ts
    pp = -(-p // ts) * ts
    a_pad = jnp.pad(a.astype(jnp.float32), ((0, mp - m), (0, np_ - n)))
    b_pad = jnp.pad(b.astype(jnp.float32), ((0, np_ - n), (0, pp - p)))
    kern = functools.partial(_masked_mm_kernel, ts=ts, m=m, n=n, p=p)
    out = pl.pallas_call(
        kern,
        grid=(mp // ts, pp // ts, np_ // ts),
        in_specs=[
            pl.BlockSpec((ts, ts), lambda i, j, k: (i, k)),
            pl.BlockSpec((ts, ts), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((ts, ts), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, pp), jnp.float32),
        interpret=True,
    )(a_pad, b_pad)
    return out[:m, :p]
