"""Darknet-style ``.cfg`` network description parser (build-time twin of
``rust/src/config/net_config.rs`` — both sides parse the same ``configs/*.cfg``
files so the model zoo has a single source of truth).

Supported sections mirror the layer types Synergy handles on the ZC702:
``[net]`` (input geometry), ``[convolutional]``, ``[maxpool]``, ``[avgpool]``,
``[connected]``, ``[batchnorm]``, ``[dropout]``, ``[softmax]``.
"""

from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Tuple

# Names of the seven benchmark networks of paper Table 2 (= configs/*.cfg).
ZOO = [
    "cifar_darknet",
    "cifar_alex",
    "cifar_alex_plus",
    "cifar_full",
    "mnist",
    "svhn",
    "mpcnn",
]


@dataclasses.dataclass
class LayerCfg:
    """One parsed ``[section]`` with its key=value options."""

    kind: str
    options: dict

    def geti(self, key: str, default: int) -> int:
        return int(self.options.get(key, default))

    def gets(self, key: str, default: str) -> str:
        return str(self.options.get(key, default))


@dataclasses.dataclass
class NetCfg:
    """A parsed network: input geometry + ordered layer list."""

    name: str
    height: int
    width: int
    channels: int
    layers: List[LayerCfg]

    @property
    def input_shape(self) -> Tuple[int, int, int]:
        return (self.channels, self.height, self.width)


def parse_cfg_text(name: str, text: str) -> NetCfg:
    """Parse darknet-style cfg text into a :class:`NetCfg`."""
    sections: List[LayerCfg] = []
    current: Optional[LayerCfg] = None
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        if line.startswith("["):
            if not line.endswith("]"):
                raise ValueError(f"{name}:{lineno}: malformed section {raw!r}")
            current = LayerCfg(kind=line[1:-1].strip().lower(), options={})
            sections.append(current)
        else:
            if current is None:
                raise ValueError(f"{name}:{lineno}: option outside a section")
            if "=" not in line:
                raise ValueError(f"{name}:{lineno}: expected key=value, got {raw!r}")
            key, value = line.split("=", 1)
            current.options[key.strip()] = value.strip()

    if not sections or sections[0].kind != "net":
        raise ValueError(f"{name}: first section must be [net]")
    net = sections[0]
    height = net.geti("height", 0)
    width = net.geti("width", 0)
    channels = net.geti("channels", 0)
    if height <= 0 or width <= 0 or channels <= 0:
        raise ValueError(f"{name}: [net] must define height/width/channels > 0")

    known = {
        "convolutional",
        "maxpool",
        "avgpool",
        "connected",
        "batchnorm",
        "dropout",
        "softmax",
    }
    for sec in sections[1:]:
        if sec.kind not in known:
            raise ValueError(f"{name}: unknown layer section [{sec.kind}]")

    return NetCfg(
        name=name,
        height=height,
        width=width,
        channels=channels,
        layers=sections[1:],
    )


def configs_dir() -> str:
    """Locate ``configs/`` relative to this file (repo root / configs)."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.normpath(os.path.join(here, "..", "..", "configs"))


def load(name: str) -> NetCfg:
    """Load ``configs/<name>.cfg``."""
    path = os.path.join(configs_dir(), f"{name}.cfg")
    with open(path, "r") as f:
        return parse_cfg_text(name, f.read())


def load_zoo() -> List[NetCfg]:
    """Load all seven benchmark networks (paper Table 2)."""
    return [load(name) for name in ZOO]
