"""AOT lowering: JAX/Pallas → HLO *text* → ``artifacts/``.

Emitted artifacts (consumed by ``rust/src/runtime``):

* ``job_mm_ts{TS}_k{K}.hlo.txt`` — the per-job PE kernel, one per distinct
  K (number of k-tiles in the shared GEMM dimension) appearing in the model
  zoo.  Signature: (A[K,TS,TS] f32, B[K,TS,TS] f32) -> (C[TS,TS] f32,).
* ``model_{name}.hlo.txt`` — the full forward pass of each benchmark CNN,
  with weights as parameters: (x, *params) -> (probs,).  Used by the Rust
  integration tests as the numerical oracle for the whole pipeline.
* ``manifest.json`` — index of the above plus the canonical parameter
  order/shapes so Rust can feed PJRT without guessing.

HLO **text** (not ``HloModuleProto.serialize``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import netcfg
from .kernels.tiled_mm import DEFAULT_TS, job_mm


def to_hlo_text(lowered) -> str:
    """Lowered jax computation → XLA HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_job_kernel(k: int, ts: int = DEFAULT_TS) -> str:
    spec = jax.ShapeDtypeStruct((k, ts, ts), jnp.float32)

    def fn(a, b):
        return (job_mm(a, b, ts=ts),)

    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def lower_model(net: netcfg.NetCfg) -> str:
    x_spec = jax.ShapeDtypeStruct(net.input_shape, jnp.float32)
    p_specs = [
        jax.ShapeDtypeStruct(tuple(s["shape"]), jnp.float32)
        for s in M.param_specs(net)
    ]

    def fn(x, *params):
        # The model artifact is the *oracle*: plain jnp ops (use_pallas=False)
        # keep it compact; the Pallas kernel path is validated separately via
        # the job kernels and pytest.
        return (M.forward(net, list(params), x, use_pallas=False),)

    return to_hlo_text(jax.jit(fn).lower(x_spec, *p_specs))


def needed_k_values(nets: List[netcfg.NetCfg]) -> List[int]:
    ks = set()
    for net in nets:
        for dims in M.conv_gemm_dims(net):
            ks.add(int(dims["k_tiles"]))
    return sorted(ks)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--ts", type=int, default=DEFAULT_TS, help="tile size")
    ap.add_argument(
        "--models",
        default="all",
        help="comma-separated model names, or 'all' (Table 2 zoo)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = netcfg.ZOO if args.models == "all" else args.models.split(",")
    nets = [netcfg.load(n) for n in names]

    manifest = {"tile_size": args.ts, "job_kernels": [], "models": []}

    for k in needed_k_values(nets):
        fname = f"job_mm_ts{args.ts}_k{k}.hlo.txt"
        text = lower_job_kernel(k, args.ts)
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["job_kernels"].append(
            {"k": k, "path": fname, "tile_size": args.ts}
        )
        print(f"[aot] {fname}: {len(text)} chars")

    for net in nets:
        fname = f"model_{net.name}.hlo.txt"
        text = lower_model(net)
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["models"].append(
            {
                "name": net.name,
                "path": fname,
                "input_shape": list(net.input_shape),
                "mops": M.model_mops(net),
                "params": [
                    {
                        "layer": s["layer"],
                        "name": s["name"],
                        "shape": list(s["shape"]),
                    }
                    for s in M.param_specs(net)
                ],
                "conv_gemms": M.conv_gemm_dims(net),
            }
        )
        print(f"[aot] {fname}: {len(text)} chars")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest.json: {len(manifest['job_kernels'])} kernels, "
          f"{len(manifest['models'])} models")


if __name__ == "__main__":
    main()
